//! Generation engine: marries the scheduler (batcher.rs) to a
//! [`DecodeBackend`] (XLA artifact session or the pure-Rust native model)
//! and the belief-state cache.  One engine thread owns the model; the
//! router (server.rs) talks to it over an mpsc channel.  The engine is
//! generic over the backend, so the continuous-batching logic is tested
//! end-to-end offline on `NativeBackend` and runs unchanged on PJRT.
//!
//! Since protocol v2 the engine STREAMS: every request carries an
//! [`EventSink`], and the decode loop emits each sampled token the
//! moment it exists — together with the slot's post-step posterior
//! uncertainty, the paper's belief signal — instead of accumulating a
//! reply.  Requests are cancellable mid-flight: a shared cancel flag
//! (set by the router on `{"cmd":"cancel"}` or client disconnect) or a
//! closed sink retires the slot at the next iteration's sweep, which
//! runs BEFORE `admit()` so a queued request takes over the freed slot
//! within the same engine iteration.  Streaming and cancellation live
//! entirely engine-side: backends keep returning raw logits, so every
//! [`DecodeBackend`] inherits both for free (DESIGN.md §S17).

use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

// Cancel flags and the LiveStats counters come from the model-checker
// shims (std re-exports in normal builds, DESIGN.md §S19); the
// request channel stays on std mpsc — intake is timeout-polled, not
// interleaving-sensitive.
use crate::mc::sync::{AtomicBool, AtomicUsize};

use anyhow::Result;

use super::batcher::{Cancelled, Feed, Finished, SchedRequest, Scheduler};
use super::prefix_cache::{ModelFingerprint, PrefixCache};
use super::sampling::{self, SamplerConfig};
use super::state_cache::BeliefStateCache;
use crate::config::ServeConfig;
use crate::runtime::backend::DecodeBackend;
use crate::tensor::IntTensor;
use crate::util::Stats;

/// One event in a request's stream, in emission order: `Started` once at
/// admit (queue time is final there), `Token` per sampled token, `Done`
/// exactly once as the terminal event (also for cancelled requests).
#[derive(Clone, Debug)]
pub enum EngineEvent {
    /// The request entered a batch slot; generation begins this
    /// iteration.
    Started { queue_ms: f64 },
    /// One sampled token.  `index` counts tokens sampled for this
    /// request (0-based); `uncertainty` is the slot's mean posterior
    /// variance AFTER the step that produced the token — the per-step
    /// belief trajectory the paper surfaces.
    Token { index: usize, token: i32, uncertainty: f32 },
    /// Terminal: the full reply (`tokens` holds every sampled token, so
    /// collecting only this event reproduces the legacy one-shot reply).
    Done(EngineResponse),
    /// Terminal: the request failed server-side — its lane of the fused
    /// prefill round returned an error.  Only the offending request is
    /// retired (its slot reset and released); the engine keeps serving
    /// every other lane.
    Failed { message: String },
}

/// Returned by [`EventSink::send`] when the receiving side is gone; the
/// engine treats it as an implicit cancel (a dead client must not keep
/// burning a batch lane).
#[derive(Clone, Copy, Debug)]
pub struct SinkClosed;

/// Where a request's events go.  The server backs this with the
/// per-connection writer thread; tests use plain mpsc senders.
pub trait EventSink: Send {
    fn send(&self, ev: EngineEvent) -> std::result::Result<(), SinkClosed>;
}

/// Full event stream into an mpsc channel (the engine-level test sink).
impl EventSink for Sender<EngineEvent> {
    fn send(&self, ev: EngineEvent) -> std::result::Result<(), SinkClosed> {
        Sender::send(self, ev).map_err(|_| SinkClosed)
    }
}

/// Collect-only compatibility sink: forwards the terminal
/// [`EngineEvent::Done`] and drops `Started`/`Token`, reproducing the
/// pre-streaming blocking behaviour for callers that only want the
/// finished reply.  Note the engine cannot observe disconnection of
/// this sink from token sends (they are swallowed here), so a dropped
/// receiver only surfaces at `Done` — use an `EngineEvent` sink where
/// implicit cancel matters.
impl EventSink for Sender<EngineResponse> {
    fn send(&self, ev: EngineEvent) -> std::result::Result<(), SinkClosed> {
        match ev {
            EngineEvent::Done(resp) => {
                Sender::send(self, resp).map_err(|_| SinkClosed)
            }
            // a failed request has no reply: dropping the sender at
            // retire surfaces to the caller as a channel disconnect,
            // matching the pre-streaming behaviour for engine errors
            EngineEvent::Started { .. }
            | EngineEvent::Token { .. }
            | EngineEvent::Failed { .. } => Ok(()),
        }
    }
}

/// A request entering the engine.
pub struct EngineRequest {
    pub prompt: Vec<i32>,
    /// Tokens to sample; 0 = prefill only (empty `tokens` reply, the
    /// belief-state `uncertainty` still reported).
    pub max_new: usize,
    /// Per-request sampling & termination config
    /// ([`SamplerConfig::greedy`] reproduces the historical behaviour
    /// exactly).
    pub sampler: SamplerConfig,
    /// Stamped by the producer at enqueue time, so queue_ms includes
    /// time spent in the mpsc channel before engine intake (under
    /// overload, intake stops draining once the scheduler queue reaches
    /// batch size — that channel wait is real queueing).
    pub submitted: Instant,
    /// Cooperative cancel flag: set it (router-side on
    /// `{"cmd":"cancel"}` or client disconnect) and the engine retires
    /// the request at its next iteration's sweep, replying with a
    /// `cancelled: true` [`EngineEvent::Done`].
    pub cancel: Arc<AtomicBool>,
    /// Destination for the request's event stream.
    pub sink: Box<dyn EventSink>,
    /// Prefix-cache participation (protocol `"cache": false` opts out);
    /// ignored (no-op) when the server runs without a prefix cache.
    pub cache: bool,
}

impl EngineRequest {
    /// A non-cancellable (flag never set) request streaming into `sink`.
    pub fn new(prompt: Vec<i32>, max_new: usize, sampler: SamplerConfig,
               sink: Box<dyn EventSink>) -> Self {
        EngineRequest {
            prompt,
            max_new,
            sampler,
            submitted: Instant::now(),
            cancel: Arc::new(AtomicBool::new(false)),
            sink,
            cache: true,
        }
    }
}

/// The terminal reply (tokens + timing; uncertainty from the belief
/// state).  `cancelled` requests carry whatever was generated before the
/// cancel took effect.
#[derive(Clone, Debug)]
pub struct EngineResponse {
    pub tokens: Vec<i32>,
    pub queue_ms: f64,
    pub total_ms: f64,
    pub uncertainty: f32,
    pub cancelled: bool,
    /// Prompt tokens this request skipped by restoring a prefix-cache
    /// snapshot at admit (0 when the cache is off, missed, or the
    /// request opted out).
    pub cached_tokens: usize,
}

/// Engine statistics (read after shutdown; live counters are mirrored
/// into [`LiveStats`] for the `{"cmd":"stats"}` protocol line).
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub requests: usize,
    /// All batched engine iterations, prefill-only ones included
    /// (chunked `prefill()` calls are not steps — their time lands in
    /// `prefill_ms`).
    pub steps: usize,
    /// Tokens of COMPLETED requests (delivered work).  Tokens decoded
    /// for requests that were cancelled mid-flight land in
    /// `wasted_tokens` instead.
    pub tokens_out: usize,
    /// Requests retired by explicit cancel or sink disconnect before
    /// completing.
    pub cancelled: usize,
    /// Requests retired because their lane of a fused prefill round
    /// returned an error (per-slot fault isolation: the engine keeps
    /// serving, only the offending request fails).
    pub failed: usize,
    /// Tokens decoded for requests that never completed (cancelled /
    /// disconnected) — abandoned work the batch lanes burned.
    pub wasted_tokens: usize,
    /// Wall time of batched steps where at least one lane sampled.
    pub step_ms: Vec<f64>,
    /// Wall time of prefill work: chunked backend `prefill()` calls plus
    /// batched steps where every live lane was still prefilling.
    pub prefill_ms: Vec<f64>,
    /// Prompt tokens consumed as prefill (chunked calls + legacy
    /// `Feed::Prefill` lanes).
    pub prefill_tokens: usize,
    pub batch_occupancy: Vec<f64>,
    /// Prefix-cache counters (all zero when the cache is disabled).
    /// Full hits cover a request's whole usable prefix; partial hits
    /// matched a shorter block-aligned shared prefix.
    pub prefix_hits: usize,
    pub prefix_partial_hits: usize,
    pub prefix_misses: usize,
    pub prefix_evictions: usize,
    /// Prompt tokens skipped by restored snapshots (prefill work saved).
    pub prefix_cached_tokens: usize,
    /// Final cache residency at engine exit.
    pub prefix_bytes: usize,
    pub prefix_entries: usize,
}

impl EngineStats {
    /// Generated tokens per second of DECODE step time.  Prefill time is
    /// excluded (it has [`Self::prefill_tokens_per_sec`] of its own) —
    /// the old formula divided by a total that included prefill steps,
    /// understating decode throughput for prompt-heavy traffic.
    pub fn tokens_per_sec(&self) -> f64 {
        let total_s: f64 = self.step_ms.iter().sum::<f64>() / 1e3;
        if total_s > 0.0 {
            self.tokens_out as f64 / total_s
        } else {
            0.0
        }
    }

    /// Prompt tokens consumed per second of prefill time.
    pub fn prefill_tokens_per_sec(&self) -> f64 {
        let total_s: f64 = self.prefill_ms.iter().sum::<f64>() / 1e3;
        if total_s > 0.0 {
            self.prefill_tokens as f64 / total_s
        } else {
            0.0
        }
    }

    pub fn mean_step_ms(&self) -> f64 {
        let mut s = Stats::new();
        for &x in &self.step_ms {
            s.push(x);
        }
        s.mean()
    }
}

/// Live engine counters, shared with the router threads so the
/// documented `{"cmd":"stats"}` line can answer DURING serving —
/// `EngineStats` itself is only returned after shutdown.
#[derive(Debug, Default)]
pub struct LiveStats {
    pub requests: AtomicUsize,
    pub steps: AtomicUsize,
    pub tokens_out: AtomicUsize,
    pub prefill_tokens: AtomicUsize,
    pub cancelled: AtomicUsize,
    pub failed: AtomicUsize,
    pub wasted_tokens: AtomicUsize,
    /// Prefix-cache mirrors (engine-thread writes via `store`, so they
    /// are point-in-time copies of the single-owner cache's counters).
    pub prefix_hits: AtomicUsize,
    pub prefix_partial_hits: AtomicUsize,
    pub prefix_misses: AtomicUsize,
    pub prefix_evictions: AtomicUsize,
    pub prefix_cached_tokens: AtomicUsize,
    pub prefix_bytes: AtomicUsize,
    pub prefix_entries: AtomicUsize,
}

/// Engine tuning knobs beyond the backend itself (threaded through from
/// [`ServeConfig`] by the server; tests construct it directly).
#[derive(Clone, Copy, Debug)]
pub struct EngineOptions {
    /// How long to wait to fill empty slots before stepping a
    /// partially-full batch.
    pub batch_window: Duration,
    /// Pad token for idle lanes and empty prompts (a real, configurable
    /// vocab id — previously hardcoded to 0).
    pub pad: i32,
    /// Max prompt tokens per backend `prefill()` call (one chunk round
    /// per slot per engine iteration); <= 1 keeps the legacy
    /// token-per-iteration prefill path, as do backends whose
    /// `prefill_is_parallel()` is false.
    pub prefill_chunk: usize,
    /// Engine seed: keys the counter-based sampling RNG
    /// (`sampling::request_key(seed, request id, client seed)`) and
    /// participates in the prefix-cache model fingerprint.
    pub seed: u64,
    /// Prefix-cache byte budget; 0 disables the cache.  Only effective
    /// on the chunked-prefill path (`prefill_chunk > 1` on a backend
    /// with a parallel prefill) — snapshot insertion points exist only
    /// there.
    pub prefix_cache_bytes: usize,
    /// Prefix-cache offset granularity in prompt tokens; 0 means "use
    /// `prefill_chunk`", which keeps every block-aligned cached offset
    /// chunk-aligned — the generation-identity condition (DESIGN.md
    /// §S15).
    pub prefix_cache_block: usize,
}

impl EngineOptions {
    pub fn from_serve(cfg: &ServeConfig) -> Self {
        // bad-config guard: chunked prefill parks cursors on multiples
        // of prefill_chunk, so a cache block that is NOT a chunk
        // multiple would never see a block-aligned cursor — the cache
        // silently degrades to end-of-prefill snapshots only.  Round UP
        // to the next chunk multiple and say so (0 keeps its "use
        // prefill_chunk" meaning; chunk <= 1 is the legacy path, where
        // the block is never consulted).
        let mut block = cfg.prefix_cache_block;
        if block > 0 && cfg.prefill_chunk > 1 && block % cfg.prefill_chunk != 0
        {
            let rounded =
                block.div_ceil(cfg.prefill_chunk) * cfg.prefill_chunk;
            crate::log_warn!(
                "prefix-cache-block {block} is not a multiple of \
                 prefill-chunk {}; rounding up to {rounded}",
                cfg.prefill_chunk);
            block = rounded;
        }
        EngineOptions {
            batch_window: Duration::from_micros(cfg.batch_window_us),
            pad: cfg.pad,
            prefill_chunk: cfg.prefill_chunk,
            seed: cfg.seed,
            prefix_cache_bytes: cfg.prefix_cache_bytes,
            prefix_cache_block: block,
        }
    }
}

/// Submit/admit/finish bookkeeping for in-flight requests, now carrying
/// each request's event sink and cancel flag.
///
/// Queue time is the interval from submit until the scheduler actually
/// admits the request into a batch slot — NOT submit-to-submit (the old
/// code stamped `start_time` at submit and never updated it, so
/// `queue_ms` was always ~0 even for requests that waited behind a full
/// batch).  `admit()` is driven by the `(slot, id)` pairs
/// `Scheduler::admit` reports, and emits the `Started` event (queue time
/// is final there).
struct PendingTable {
    rows: Vec<PendingRow>,
}

struct PendingRow {
    id: u64,
    sink: Box<dyn EventSink>,
    cancel: Arc<AtomicBool>,
    submitted: Instant,
    admitted: Option<Instant>,
    /// A sink send failed: the client is gone.  Latched so the sweep
    /// retires the request (implicit cancel) and no further sends are
    /// attempted.
    sink_closed: bool,
    /// Prompt tokens skipped via a restored prefix-cache snapshot,
    /// recorded at admit and reported on the `Done` event.
    cached_tokens: usize,
}

impl PendingTable {
    fn new() -> Self {
        PendingTable { rows: Vec::new() }
    }

    fn submit(&mut self, id: u64, sink: Box<dyn EventSink>,
              cancel: Arc<AtomicBool>, now: Instant) {
        self.rows.push(PendingRow {
            id,
            sink,
            cancel,
            submitted: now,
            admitted: None,
            sink_closed: false,
            cached_tokens: 0,
        });
    }

    /// Record the moment `id` entered a batch slot (idempotent) and
    /// stream the `Started` event.  `cached_tokens` is the prefix-cache
    /// restore credit granted at this admit.
    fn admit(&mut self, id: u64, now: Instant, cached_tokens: usize) {
        if let Some(row) = self.rows.iter_mut().find(|r| r.id == id) {
            if row.admitted.is_none() {
                row.admitted = Some(now);
                row.cached_tokens = cached_tokens;
                let queue_ms = now
                    .saturating_duration_since(row.submitted)
                    .as_secs_f64()
                    * 1e3;
                if row.sink.send(EngineEvent::Started { queue_ms }).is_err() {
                    row.sink_closed = true;
                }
            }
        }
    }

    /// Stream one sampled token; a failed send latches `sink_closed`
    /// (the sweep turns it into an implicit cancel next iteration).
    fn emit_token(&mut self, id: u64, index: usize, token: i32,
                  uncertainty: f32) {
        if let Some(row) = self.rows.iter_mut().find(|r| r.id == id) {
            if row.sink_closed {
                return;
            }
            let ev = EngineEvent::Token { index, token, uncertainty };
            if row.sink.send(ev).is_err() {
                row.sink_closed = true;
            }
        }
    }

    /// Requests to retire at the next sweep: cancel flag set by the
    /// router, or sink observed closed (client gone — implicit cancel).
    fn dead_ids(&self) -> Vec<u64> {
        // ord: SeqCst — the cancel flag is a cross-thread control
        // edge (router store -> engine sweep load); strongest
        // ordering, and it is nowhere near the hot path.
        self.rows
            .iter()
            .filter(|r| r.sink_closed || r.cancel.load(Ordering::SeqCst))
            .map(|r| r.id)
            .collect()
    }

    /// Retire `id`: returns the sink plus `(queue_ms, total_ms,
    /// cached_tokens)` measured at `now`.
    fn finish(&mut self, id: u64, now: Instant)
              -> Option<(Box<dyn EventSink>, f64, f64, usize)> {
        let pos = self.rows.iter().position(|r| r.id == id)?;
        let row = self.rows.swap_remove(pos);
        let admitted = row.admitted.unwrap_or(now);
        let queue_ms =
            admitted.saturating_duration_since(row.submitted).as_secs_f64()
                * 1e3;
        let total_ms =
            now.saturating_duration_since(row.submitted).as_secs_f64() * 1e3;
        Some((row.sink, queue_ms, total_ms, row.cached_tokens))
    }
}

/// Retire one finished request: account its tokens, read the slot's
/// belief uncertainty, reset + release the slot, and stream the terminal
/// `Done` event.  Shared by the decode path (`Scheduler::advance`) and
/// the prefill-only path (`Scheduler::take_prefill_only_finished`).
fn finish_request(f: &Finished, cache: &mut BeliefStateCache,
                  sched: &mut Scheduler, pending: &mut PendingTable,
                  stats: &mut EngineStats, live: &LiveStats) {
    stats.tokens_out += f.tokens.len();
    // ord: Relaxed — monotonic stats counter mirrored for the stats
    // endpoint; readers tolerate staleness, no ordering needed.
    live.tokens_out.fetch_add(f.tokens.len(), Ordering::Relaxed);
    let uncertainty = cache.slot_uncertainty(f.slot);
    cache.reset_slot(f.slot);
    sched.release(f.slot);
    if let Some((sink, queue_ms, total_ms, cached_tokens)) =
        pending.finish(f.id, Instant::now())
    {
        let _ = sink.send(EngineEvent::Done(EngineResponse {
            tokens: f.tokens.clone(),
            queue_ms,
            total_ms,
            uncertainty,
            cancelled: false,
            cached_tokens,
        }));
    }
}

/// Mirror the prefix cache's counters into the shared [`LiveStats`] so
/// the `{"cmd":"stats"}` protocol line answers during serving.
fn sync_prefix_live(pc: &PrefixCache, live: &LiveStats) {
    let s = pc.stats();
    // ord: Relaxed — stats mirror for the protocol endpoint; the
    // seven stores need no ordering among themselves or with
    // anything else, readers tolerate a torn snapshot.
    live.prefix_hits.store(s.hits, Ordering::Relaxed);
    live.prefix_partial_hits.store(s.partial_hits, Ordering::Relaxed);
    live.prefix_misses.store(s.misses, Ordering::Relaxed);
    live.prefix_evictions.store(s.evictions, Ordering::Relaxed);
    live.prefix_cached_tokens.store(s.cached_tokens, Ordering::Relaxed);
    live.prefix_bytes.store(s.bytes, Ordering::Relaxed);
    live.prefix_entries.store(s.entries, Ordering::Relaxed);
}

/// Run the engine loop until `rx` disconnects (or `shutdown` is set) and
/// all admitted work drains.  `batch_window` bounds how long we wait to
/// fill empty slots before stepping a partially-full batch.
///
/// The intake NEVER blocks indefinitely: connection-handler threads hold
/// `tx` clones for as long as their sockets live, so a blocking `recv()`
/// would deadlock `ServerHandle::stop()` against any client that keeps its
/// connection open (seen in integration_serve).
pub fn run_engine<B: DecodeBackend>(backend: &B,
                                    rx: Receiver<EngineRequest>,
                                    batch_window: Duration,
                                    shutdown: Arc<AtomicBool>)
                                    -> Result<EngineStats> {
    let opts = EngineOptions {
        batch_window,
        ..EngineOptions::from_serve(&ServeConfig::default())
    };
    run_engine_opts(backend, rx, &opts, shutdown,
                    &Arc::new(LiveStats::default()))
}

/// [`run_engine`] with explicit [`EngineOptions`] and shared
/// [`LiveStats`] counters (the server passes the same `Arc` to the
/// router threads for the `stats` protocol line).
pub fn run_engine_opts<B: DecodeBackend>(backend: &B,
                                         rx: Receiver<EngineRequest>,
                                         opts: &EngineOptions,
                                         shutdown: Arc<AtomicBool>,
                                         live: &Arc<LiveStats>)
                                         -> Result<EngineStats> {
    let b = backend.batch();
    let batch_window = opts.batch_window;
    let mut cache = BeliefStateCache::for_backend(backend)?;
    // prefix cache: chunked-prefill only — snapshot insertion points
    // (block-aligned prefill cursors) exist only on that path, and the
    // legacy token-per-iteration path has no per-slot state extraction
    // moment.  Fingerprinted so a snapshot can never restore into a
    // mismatched model (DESIGN.md §S15).
    let chunked = opts.prefill_chunk > 1 && backend.prefill_is_parallel();
    let mut pcache = if opts.prefix_cache_bytes > 0 && chunked {
        let block = if opts.prefix_cache_block > 0 {
            opts.prefix_cache_block
        } else {
            opts.prefill_chunk
        };
        Some((ModelFingerprint::for_backend(backend, opts.seed)?,
              PrefixCache::new(block, opts.prefix_cache_bytes)))
    } else {
        None
    };
    let mut sched = Scheduler::new(b, opts.pad);
    // engine-owned prefill: mid-prefill slots are Idle in the batched
    // step and their cursors only move through take_prefill, so they
    // stay on the k * chunk grid block-aligned snapshots need
    sched.set_chunked_prefill(chunked);
    let mut pending = PendingTable::new();
    let mut next_id = 0u64;
    let mut stats = EngineStats::default();
    let mut disconnected = false;
    // token ids are clamped into [0, vocab) before any backend call so
    // the trait contract holds for every backend (the XLA gather has no
    // clamp of its own)
    let vmax = crate::util::cast::vocab_max_token(backend.vocab());

    // ord: SeqCst — process-wide shutdown latch; set once by the
    // server, polled here between iterations.  Not hot, keep strong.
    while (!disconnected && !shutdown.load(Ordering::SeqCst))
        || sched.has_work()
    {
        // intake: block briefly when idle, else drain without blocking
        let deadline = Instant::now() + batch_window;
        loop {
            let timeout = deadline.saturating_duration_since(Instant::now());
            let msg = if sched.active_count() == 0 && sched.queue.is_empty()
            {
                // fully idle: wait in short slices so shutdown is observed
                match rx.recv_timeout(Duration::from_millis(20)) {
                    Ok(m) => Some(m),
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                        // ord: SeqCst — same shutdown latch as the
                        // loop condition above.
                        if shutdown.load(Ordering::SeqCst) {
                            disconnected = true;
                        }
                        None
                    }
                    Err(_) => {
                        disconnected = true;
                        None
                    }
                }
            } else if sched.queue.is_empty()
                && sched.active_count() < b
                && !timeout.is_zero()
            {
                match rx.recv_timeout(timeout) {
                    Ok(m) => Some(m),
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
                    Err(_) => {
                        disconnected = true;
                        None
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => Some(m),
                    Err(std::sync::mpsc::TryRecvError::Empty) => None,
                    Err(_) => {
                        disconnected = true;
                        None
                    }
                }
            };
            match msg {
                Some(req) => {
                    let id = next_id;
                    next_id += 1;
                    pending.submit(id, req.sink, req.cancel, req.submitted);
                    // RNG key stamped here: explicit client seeds make it
                    // independent of the engine-assigned id (and thus of
                    // arrival order / batch composition)
                    let key = sampling::request_key(opts.seed, id,
                                                    req.sampler.seed);
                    sched.submit(SchedRequest {
                        id,
                        prompt: req.prompt,
                        max_new: req.max_new,
                        sampler: req.sampler,
                        key,
                        cache: req.cache,
                    });
                    stats.requests += 1;
                    // ord: Relaxed — stats mirror, no ordering needed.
                    live.requests.fetch_add(1, Ordering::Relaxed);
                }
                None => break,
            }
            if sched.queue.len() >= b {
                break;
            }
        }

        // cancellation sweep: explicit cancel flags set by the router
        // ({"cmd":"cancel"} / client disconnect) plus sinks observed
        // closed mid-stream (implicit cancel — a dead connection must
        // not keep burning a batch lane).  Runs BEFORE admit(), so a
        // slot freed here is re-filled from the queue within the SAME
        // engine iteration.
        for id in pending.dead_ids() {
            let (tokens, uncertainty) = match sched.cancel(id) {
                Some(Cancelled::Active(f)) => {
                    let u = cache.slot_uncertainty(f.slot);
                    cache.reset_slot(f.slot);
                    sched.release(f.slot);
                    (f.tokens, u)
                }
                // queued (or, defensively, already gone): no slot state
                Some(Cancelled::Queued) | None => (Vec::new(), 0.0),
            };
            stats.cancelled += 1;
            // ord: Relaxed — stats mirrors, no ordering needed.
            live.cancelled.fetch_add(1, Ordering::Relaxed);
            stats.wasted_tokens += tokens.len();
            live.wasted_tokens.fetch_add(tokens.len(), Ordering::Relaxed);
            if let Some((sink, queue_ms, total_ms, cached_tokens)) =
                pending.finish(id, Instant::now())
            {
                let _ = sink.send(EngineEvent::Done(EngineResponse {
                    tokens,
                    queue_ms,
                    total_ms,
                    uncertainty,
                    cancelled: true,
                    cached_tokens,
                }));
            }
        }
        if !sched.has_work() {
            continue;
        }

        // admit into slots: reset belief state for new slots and stamp
        // the admit time (queue time ends here; Started streams out).
        // With a prefix cache, the longest cached snapshot matching the
        // new prompt is restored into the slot and the prefill cursor
        // jumps past the covered tokens — the cold prefill for a shared
        // system prompt happens exactly once.
        let admit_now = Instant::now();
        for (slot, id) in sched.admit() {
            cache.reset_slot(slot);
            let mut cached = 0usize;
            if let Some((fp, pc)) = pcache.as_mut() {
                let hit = match sched.prefill_view(slot) {
                    Some(v) if v.cache && v.usable() > 0 => {
                        pc.lookup(fp, v.prompt, v.usable())
                    }
                    _ => None,
                };
                if let Some((off, snap)) = hit {
                    // the fingerprint guarantees geometric compatibility;
                    // a restore failure here would be a cache-corruption
                    // bug, so fall back to a cold prefill defensively
                    if cache.restore(slot, snap).is_ok() {
                        cached = sched.skip_prefill(slot, off);
                    }
                }
            }
            pending.admit(id, admit_now, cached);
        }
        if let Some((_, pc)) = &pcache {
            sync_prefix_live(pc, live);
        }

        // fused (slots × time) chunked prefill: ONE multi-dimensional
        // round per engine iteration — every prefilling slot contributes
        // up to prefill_chunk prompt tokens, and the whole ragged batch
        // goes through a single backend prefill_batch() call (lane-
        // chained across the shared thread pool on the native backend;
        // the trait's per-slot fallback keeps the XLA path at exactly
        // its old cost).  In-flight decode lanes stall by at most one
        // round per iteration, and every lane carries its OWN Result: a
        // failing lane fails only its request, never its neighbours or
        // the engine.  Mid-prefill slots stay Idle in the batched step
        // below (Scheduler::set_chunked_prefill), so cursors remain on
        // the k * chunk grid block-aligned snapshot insertion needs.
        // Skipped entirely at prefill_chunk <= 1, and for backends whose
        // prefill() is the sequential fallback (XLA) — for those,
        // chunked prefill would cost dedicated batch-wide steps the
        // interleaved path shares.
        if chunked {
            let mut lanes: Vec<(usize, Vec<i32>)> = Vec::new();
            for slot in 0..b {
                let toks = sched.take_prefill(slot, opts.prefill_chunk);
                if toks.is_empty() {
                    continue;
                }
                lanes.push((slot,
                            toks.iter()
                                .map(|&t| t.clamp(0, vmax))
                                .collect()));
            }
            if !lanes.is_empty() {
                let ragged: Vec<(usize, &[i32])> = lanes
                    .iter()
                    .map(|(s, t)| (*s, t.as_slice()))
                    .collect();
                let t0 = Instant::now();
                let rows = backend.prefill_batch(&ragged, cache.state());
                // one timing entry per fused round, not per lane
                stats.prefill_ms.push(t0.elapsed().as_secs_f64() * 1e3);
                for (slot, row) in rows {
                    let n_toks = ragged
                        .iter()
                        .find(|(s, _)| *s == slot)
                        .map_or(0, |(_, t)| t.len());
                    match row {
                        Ok((_, lane)) => {
                            cache.write_slot(slot, &lane)?;
                            stats.prefill_tokens += n_toks;
                            // ord: Relaxed — stats mirror.
                            live.prefill_tokens
                                .fetch_add(n_toks, Ordering::Relaxed);
                            // prefix cache: snapshot the slot at block-
                            // aligned cursors and at the end of prefill,
                            // keyed by the exact tokens consumed so far.
                            // The end-of-prefill snapshot is what exact-
                            // prompt resubmissions full-hit; block-
                            // aligned ones serve shared-prefix partial
                            // hits.  Warm requests re-walk the same
                            // offsets — the duplicate insert is a
                            // recency refresh, not a second copy.
                            if let Some((fp, pc)) = pcache.as_mut() {
                                if let Some(v) = sched.prefill_view(slot)
                                {
                                    let done = v.cursor + v.keep
                                        == v.prompt.len();
                                    if v.cache
                                        && (v.cursor % pc.block() == 0
                                            || done)
                                    {
                                        if let Some(prefix) =
                                            v.prompt.get(..v.cursor)
                                        {
                                            pc.insert(
                                                fp, prefix,
                                                cache.snapshot(slot));
                                        }
                                    }
                                }
                            }
                        }
                        Err(e) => {
                            // per-request fault isolation: the lane's
                            // belief state may be mid-write — reset it,
                            // retire ONLY this request with a terminal
                            // Failed event, and keep serving
                            cache.reset_slot(slot);
                            if let Some(id) = sched.slot_id(slot) {
                                let _ = sched.cancel(id);
                                sched.release(slot);
                                stats.failed += 1;
                                // ord: Relaxed — stats mirror.
                                live.failed
                                    .fetch_add(1, Ordering::Relaxed);
                                if let Some((sink, ..)) =
                                    pending.finish(id, Instant::now())
                                {
                                    let _ = sink.send(
                                        EngineEvent::Failed {
                                            message: format!(
                                                "prefill failed: {e}"),
                                        });
                                }
                            }
                        }
                    }
                }
            }
            if let Some((_, pc)) = &pcache {
                sync_prefix_live(pc, live);
            }
        }

        // prefill-only requests (max_new == 0) whose prompt was fully
        // consumed by chunked prefill finish HERE, before the batched
        // step, so the reported uncertainty reflects exactly the prompt
        // (never a stray pad feed).  On the legacy path their last
        // prompt token flows through Feed::Prefill and advance() retires
        // them below instead.
        for f in sched.take_prefill_only_finished() {
            finish_request(&f, &mut cache, &mut sched, &mut pending,
                           &mut stats, live);
        }
        if !sched.has_work() {
            continue;
        }

        // build the token vector for this iteration
        let feeds = sched.feeds();
        let tokens: Vec<i32> = feeds
            .iter()
            .map(|f| match f {
                Feed::Prefill(t) | Feed::Decode(t) => (*t).clamp(0, vmax),
                Feed::Idle => sched.pad().clamp(0, vmax),
            })
            .collect();
        // occupancy counts the lanes doing real work in THIS step —
        // derived from the feeds themselves, not slot bookkeeping, so
        // finished-but-unreleased slots can never inflate it
        let live_lanes =
            feeds.iter().filter(|f| !matches!(f, Feed::Idle)).count();
        // every active lane still mid-prefill (chunked mode reports them
        // Idle): there is nothing to step — a batch-wide pad step would
        // only burn compute and pollute the step/occupancy meters
        if live_lanes == 0 {
            continue;
        }
        let any_decode =
            feeds.iter().any(|f| matches!(f, Feed::Decode(_)));
        let legacy_prefill_lanes =
            feeds.iter().filter(|f| matches!(f, Feed::Prefill(_))).count();

        // shield mid-prefill lanes through the mixed batched step: they
        // are fed pad (Feed::Idle), and without restoring afterwards the
        // pad step would advance — i.e. corrupt — the belief state their
        // next chunked round continues from
        let mut shielded: Vec<(usize, _)> = Vec::new();
        if chunked {
            for slot in 0..b {
                if let Some(v) = sched.prefill_view(slot) {
                    if v.cursor + v.keep < v.prompt.len() {
                        shielded.push((slot, cache.snapshot(slot)));
                    }
                }
            }
        }

        let t0 = Instant::now();
        let (logits, new_state) =
            backend.step(&IntTensor::new(&[b], tokens)?, cache.state())?;
        cache.set_state(new_state);
        for (slot, snap) in &shielded {
            cache.restore(*slot, snap)?;
        }
        let elapsed_ms = t0.elapsed().as_secs_f64() * 1e3;
        // apportion the step's wall time between the prefill and decode
        // meters by lane fraction, so a mixed step (some lanes still
        // consuming prompt, some sampling) charges each side fairly —
        // without this, prefill lanes' tokens were counted against only
        // the rare pure-prefill steps' time, inflating
        // prefill_tokens_per_sec and diluting tokens_per_sec
        let prefill_frac =
            legacy_prefill_lanes as f64 / live_lanes.max(1) as f64;
        if legacy_prefill_lanes > 0 {
            stats.prefill_ms.push(elapsed_ms * prefill_frac);
        }
        if any_decode {
            stats.step_ms.push(elapsed_ms * (1.0 - prefill_frac));
        }
        stats.steps += 1;
        // ord: Relaxed — stats mirrors, no ordering needed.
        live.steps.fetch_add(1, Ordering::Relaxed);
        if legacy_prefill_lanes > 0 {
            stats.prefill_tokens += legacy_prefill_lanes;
            live.prefill_tokens.fetch_add(legacy_prefill_lanes,
                                          Ordering::Relaxed);
        }
        stats.batch_occupancy.push(live_lanes as f64 / b as f64);

        // per-lane sampling: each Decode lane samples under ITS request's
        // SamplerConfig with the counter-based draw for (key, tokens
        // sampled so far) — greedy configs reduce to the exact NaN-aware
        // argmax the old batched argmax_last path computed.  The state is
        // already post-step, so the uncertainty feeding the
        // uncertainty-scaled temperature (and streamed on the token
        // event) reflects the current token.
        let vocab = backend.vocab();
        let mut sampled = vec![0i32; b];
        for (slot, f) in feeds.iter().enumerate() {
            if !matches!(f, Feed::Decode(_)) {
                continue;
            }
            let Some((cfg, key, counter)) = sched.sampling_lane(slot)
            else {
                continue;
            };
            // one posterior read per lane, shared by the uncertainty-
            // scaled temperature (an exact no-op at uncertainty_temp ==
            // 0, since tau_eff = tau * (1 + 0 * u)) and the token event
            let unc = cache.slot_uncertainty(slot);
            let Some(row) =
                logits.data().get(slot * vocab..(slot + 1) * vocab)
            else {
                continue; // backend returned fewer rows than lanes
            };
            let tok = sampling::sample(row, cfg, key, counter, unc);
            if let Some(s) = sampled.get_mut(slot) {
                *s = tok;
            }
            // stream the token the moment it exists, tagged with the
            // slot's post-step posterior uncertainty; a failed send
            // latches the implicit cancel for next iteration's sweep
            if let Some(id) = sched.slot_id(slot) {
                pending.emit_token(id, counter as usize, tok, unc);
            }
        }
        let finished = sched.advance(&sampled);
        for f in &finished {
            finish_request(f, &mut cache, &mut sched, &mut pending,
                           &mut stats, live);
        }
    }
    if let Some((_, pc)) = &pcache {
        let s = pc.stats();
        stats.prefix_hits = s.hits;
        stats.prefix_partial_hits = s.partial_hits;
        stats.prefix_misses = s.misses;
        stats.prefix_evictions = s.evictions;
        stats.prefix_cached_tokens = s.cached_tokens;
        stats.prefix_bytes = s.bytes;
        stats.prefix_entries = s.entries;
        sync_prefix_live(pc, live);
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn plain_flag() -> Arc<AtomicBool> {
        Arc::new(AtomicBool::new(false))
    }

    /// Baseline options for engine tests: prefix cache OFF (tests that
    /// exercise it override `prefix_cache_bytes` via struct update).
    fn test_opts(prefill_chunk: usize, seed: u64) -> EngineOptions {
        EngineOptions {
            batch_window: Duration::from_micros(100),
            pad: 0,
            prefill_chunk,
            seed,
            prefix_cache_bytes: 0,
            prefix_cache_block: 0,
        }
    }

    #[test]
    fn queue_time_measured_at_admit_not_submit() {
        let (tx, _rx) = channel::<EngineResponse>();
        let mut table = PendingTable::new();
        let t0 = Instant::now();
        table.submit(1, Box::new(tx), plain_flag(), t0);
        let admit = t0 + Duration::from_millis(25);
        table.admit(1, admit, 0);
        // a later admit call must not move the stamp (idempotent)
        table.admit(1, admit + Duration::from_millis(50), 0);
        let finish = admit + Duration::from_millis(10);
        let (_sink, queue_ms, total_ms, _cached) =
            table.finish(1, finish).unwrap();
        assert!((queue_ms - 25.0).abs() < 1e-6, "queue_ms {queue_ms}");
        assert!((total_ms - 35.0).abs() < 1e-6, "total_ms {total_ms}");
        // finished rows are gone
        assert!(table.finish(1, finish).is_none());
    }

    #[test]
    fn pending_table_latches_closed_sinks_as_dead() {
        let (tx, rx) = channel::<EngineEvent>();
        let mut table = PendingTable::new();
        let t0 = Instant::now();
        table.submit(3, Box::new(tx), plain_flag(), t0);
        table.admit(3, t0, 0);
        assert!(matches!(rx.recv().unwrap(),
                         EngineEvent::Started { .. }));
        assert!(table.dead_ids().is_empty());
        // receiver gone: the next emission latches sink_closed
        drop(rx);
        table.emit_token(3, 0, 7, 0.5);
        assert_eq!(table.dead_ids(), vec![3]);
        // the cancel flag alone also marks a row dead
        let (tx2, _rx2) = channel::<EngineResponse>();
        let flag = plain_flag();
        table.submit(4, Box::new(tx2), flag.clone(), t0);
        assert_eq!(table.dead_ids(), vec![3]);
        flag.store(true, Ordering::SeqCst);
        let mut dead = table.dead_ids();
        dead.sort_unstable();
        assert_eq!(dead, vec![3, 4]);
    }

    fn tiny_backend(batch: usize) -> crate::runtime::backend::NativeBackend {
        use crate::kla::model::NativeLmConfig;
        let cfg = NativeLmConfig {
            vocab: 16,
            d_model: 8,
            n_layers: 1,
            n_state: 2,
            conv_kernel: 3,
            ..Default::default()
        };
        crate::runtime::backend::NativeBackend::seeded(&cfg, 1, batch)
    }

    fn one_request(prompt: Vec<i32>, max_new: usize)
                   -> (Receiver<EngineRequest>,
                       Receiver<EngineResponse>) {
        one_request_with(prompt, max_new, SamplerConfig::greedy())
    }

    fn one_request_with(prompt: Vec<i32>, max_new: usize,
                        sampler: SamplerConfig)
                        -> (Receiver<EngineRequest>,
                            Receiver<EngineResponse>) {
        let (tx, rx) = channel::<EngineRequest>();
        let (rtx, rrx) = channel::<EngineResponse>();
        tx.send(EngineRequest::new(prompt, max_new, sampler,
                                   Box::new(rtx)))
            .unwrap();
        drop(tx);
        (rx, rrx)
    }

    #[test]
    fn chunked_prefill_splits_timings_and_counts_occupancy() {
        let backend = tiny_backend(2);
        let prompt: Vec<i32> = (0..17).map(|i| i % 16).collect();
        let (rx, rrx) = one_request(prompt, 3);
        let live = Arc::new(LiveStats::default());
        let opts = test_opts(8, 0);
        let stats = run_engine_opts(&backend, rx, &opts,
                                    Arc::new(AtomicBool::new(false)),
                                    &live)
            .unwrap();
        assert_eq!(rrx.recv().unwrap().tokens.len(), 3);
        // 16 prefill tokens through two fused rounds (8 + 8): one
        // prefill_ms entry per ROUND, and no stray Feed::Prefill token
        // between them (the mid-prefill slot is Idle in the batched
        // step, so the cursor stays on the chunk grid)
        assert_eq!(stats.prefill_tokens, 16);
        assert_eq!(stats.prefill_ms.len(), 2);
        // batched steps: 3 sampled decode steps (last prompt token + 2
        // generated); the all-mid-prefill iteration steps nothing
        assert_eq!(stats.steps, 3);
        assert_eq!(stats.step_ms.len(), 3);
        assert_eq!(stats.tokens_out, 3);
        assert_eq!(stats.cancelled, 0);
        assert_eq!(stats.wasted_tokens, 0);
        assert!(stats.tokens_per_sec() > 0.0);
        assert!(stats.prefill_tokens_per_sec() > 0.0);
        // one request on a 2-slot engine: every step at occupancy 1/2
        assert!(!stats.batch_occupancy.is_empty());
        assert!(stats.batch_occupancy
            .iter()
            .all(|&o| (o - 0.5).abs() < 1e-9),
                "occupancy {:?}", stats.batch_occupancy);
        // live mirror saw the same counters
        assert_eq!(live.requests.load(Ordering::SeqCst), 1);
        assert_eq!(live.steps.load(Ordering::SeqCst), 3);
        assert_eq!(live.tokens_out.load(Ordering::SeqCst), 3);
        assert_eq!(live.prefill_tokens.load(Ordering::SeqCst), 16);
        assert_eq!(live.cancelled.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn legacy_prefill_steps_are_metered_as_prefill_not_decode() {
        let backend = tiny_backend(1);
        let (rx, rrx) = one_request(vec![1, 2, 3, 4, 5], 1);
        let live = Arc::new(LiveStats::default());
        let opts = test_opts(1, 0); // legacy token-per-iteration path
        let stats = run_engine_opts(&backend, rx, &opts,
                                    Arc::new(AtomicBool::new(false)),
                                    &live)
            .unwrap();
        assert_eq!(rrx.recv().unwrap().tokens.len(), 1);
        // four Feed::Prefill iterations, then one sampled Decode step
        assert_eq!(stats.prefill_tokens, 4);
        assert_eq!(stats.prefill_ms.len(), 4);
        assert_eq!(stats.step_ms.len(), 1);
        assert_eq!(stats.steps, 5);
        assert_eq!(stats.tokens_out, 1);
        // single-slot engine fully occupied throughout
        assert!(stats.batch_occupancy.iter().all(|&o| o == 1.0));
    }

    #[test]
    fn pad_option_reaches_the_scheduler() {
        let backend = tiny_backend(1);
        // empty prompt: the scheduler substitutes the configured pad
        // token, and generation still works (pad 9 is a live vocab id)
        let (rx, rrx) = one_request(vec![], 2);
        let opts = EngineOptions { pad: 9, ..test_opts(64, 0) };
        let stats = run_engine_opts(&backend, rx, &opts,
                                    Arc::new(AtomicBool::new(false)),
                                    &Arc::new(LiveStats::default()))
            .unwrap();
        assert_eq!(rrx.recv().unwrap().tokens.len(), 2);
        assert_eq!(stats.tokens_out, 2);
    }

    #[test]
    fn zero_max_new_is_prefill_only_on_the_chunked_path() {
        let backend = tiny_backend(2);
        let (rx, rrx) = one_request((0..12).map(|i| i % 16).collect(), 0);
        let opts = test_opts(8, 0);
        let stats = run_engine_opts(&backend, rx, &opts,
                                    Arc::new(AtomicBool::new(false)),
                                    &Arc::new(LiveStats::default()))
            .unwrap();
        let resp = rrx.recv().unwrap();
        // no tokens generated, but the prompt WAS consumed and the
        // belief-state uncertainty is reported
        assert!(resp.tokens.is_empty());
        assert!(!resp.cancelled);
        assert!(resp.uncertainty > 0.0);
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.tokens_out, 0);
        // two fused rounds (8 + 4) consume the whole prompt; no batched
        // step ever runs for a prefill-only request
        assert_eq!(stats.prefill_tokens, 12);
        assert_eq!(stats.prefill_ms.len(), 2);
        assert_eq!(stats.steps, 0);
        assert!(stats.step_ms.is_empty());
    }

    #[test]
    fn zero_max_new_is_prefill_only_on_the_legacy_path() {
        let backend = tiny_backend(1);
        let (rx, rrx) = one_request(vec![1, 2, 3], 0);
        let opts = test_opts(1, 0);
        let stats = run_engine_opts(&backend, rx, &opts,
                                    Arc::new(AtomicBool::new(false)),
                                    &Arc::new(LiveStats::default()))
            .unwrap();
        let resp = rrx.recv().unwrap();
        assert!(resp.tokens.is_empty());
        assert!(resp.uncertainty > 0.0);
        assert_eq!(stats.tokens_out, 0);
        // all three prompt tokens flowed through Feed::Prefill (the last
        // one is NOT a sampled Decode feed when max_new == 0)
        assert_eq!(stats.prefill_tokens, 3);
        assert!(stats.step_ms.is_empty(), "no decode step may run");
    }

    #[test]
    fn seeded_sampling_is_reproducible_and_differs_from_greedy_keyspace()
    {
        // same explicit client seed => identical tokens across engines;
        // the counter-based draws make this independent of everything
        // else (pinned end-to-end against batch width in
        // integration_serve)
        let run = |client_seed: Option<u64>| -> Vec<i32> {
            let backend = tiny_backend(2);
            let sampler = SamplerConfig {
                temperature: 1.2,
                top_p: 0.95,
                seed: client_seed,
                ..SamplerConfig::greedy()
            };
            let (rx, rrx) =
                one_request_with(vec![1, 2, 3], 8, sampler);
            let opts = test_opts(64, 7);
            run_engine_opts(&backend, rx, &opts,
                            Arc::new(AtomicBool::new(false)),
                            &Arc::new(LiveStats::default()))
                .unwrap();
            rrx.recv().unwrap().tokens
        };
        let a = run(Some(99));
        let b = run(Some(99));
        assert_eq!(a, b, "same client seed must reproduce");
        assert_eq!(a.len(), 8);
        // without a client seed the id-derived key is still reproducible
        // for the same arrival order
        assert_eq!(run(None), run(None));
    }

    #[test]
    fn stop_token_ends_generation_early_through_the_engine() {
        // greedy pass to learn the model's continuation, then stop on
        // its second generated token
        let full = {
            let backend = tiny_backend(1);
            let (rx, rrx) = one_request(vec![4, 9], 6);
            run_engine(&backend, rx, Duration::from_micros(100),
                       Arc::new(AtomicBool::new(false)))
                .unwrap();
            rrx.recv().unwrap().tokens
        };
        assert_eq!(full.len(), 6);
        let stop = full[1];
        let first = full.iter().position(|&t| t == stop).unwrap();
        let backend = tiny_backend(1);
        let sampler = SamplerConfig {
            stop_tokens: vec![stop],
            ..SamplerConfig::greedy()
        };
        let (rx, rrx) = one_request_with(vec![4, 9], 6, sampler);
        let stats = run_engine(&backend, rx, Duration::from_micros(100),
                               Arc::new(AtomicBool::new(false)))
            .unwrap();
        let got = rrx.recv().unwrap().tokens;
        // terminated at the first occurrence, stop token included
        assert_eq!(got, full[..=first].to_vec());
        assert_eq!(stats.tokens_out, first + 1);
    }

    #[test]
    fn streamed_token_events_match_the_done_reply() {
        // full event-stream contract: Started, then one Token per
        // sampled token (contiguous indices, post-step uncertainty),
        // then Done whose tokens array equals the concatenated stream
        let backend = tiny_backend(1);
        let (tx, rx) = channel::<EngineRequest>();
        let (etx, erx) = channel::<EngineEvent>();
        tx.send(EngineRequest::new(vec![2, 5, 11], 5,
                                   SamplerConfig::greedy(),
                                   Box::new(etx)))
            .unwrap();
        drop(tx);
        run_engine(&backend, rx, Duration::from_micros(100),
                   Arc::new(AtomicBool::new(false)))
            .unwrap();
        let events: Vec<EngineEvent> = erx.iter().collect();
        assert!(matches!(events[0], EngineEvent::Started { queue_ms }
                         if queue_ms >= 0.0));
        let mut streamed = Vec::new();
        let mut last_unc = 0.0f32;
        let mut done = None;
        for ev in &events[1..] {
            match ev {
                EngineEvent::Token { index, token, uncertainty } => {
                    assert_eq!(*index, streamed.len(),
                               "token indices must be contiguous");
                    assert!(*uncertainty > 0.0);
                    streamed.push(*token);
                    last_unc = *uncertainty;
                }
                EngineEvent::Done(resp) => {
                    assert!(done.is_none(), "Done must be terminal");
                    done = Some(resp.clone());
                }
                EngineEvent::Started { .. } => {
                    panic!("Started must come exactly once, first");
                }
            }
        }
        let done = done.expect("stream must end in Done");
        assert_eq!(streamed.len(), 5);
        assert_eq!(done.tokens, streamed,
                   "Done.tokens must equal the concatenated stream");
        assert!(!done.cancelled);
        // the final token's streamed uncertainty IS the reply's (same
        // post-step state, read twice)
        assert!((done.uncertainty - last_unc).abs() < 1e-12);
    }

    #[test]
    fn disconnected_event_sink_cancels_and_frees_the_slot() {
        // request A streams into a channel whose receiver is ALREADY
        // gone: the first failed send latches the implicit cancel, the
        // sweep retires the slot, and queued request B takes it over —
        // without the fix A would decode all 1_000_000 tokens into the
        // void first
        let backend = tiny_backend(1);
        let (tx, rx) = channel::<EngineRequest>();
        let (etx, erx) = channel::<EngineEvent>();
        drop(erx); // the "client" vanished before the engine even ran
        tx.send(EngineRequest::new(vec![1, 2], 1_000_000,
                                   SamplerConfig::greedy(),
                                   Box::new(etx)))
            .unwrap();
        let (rtx, rrx) = channel::<EngineResponse>();
        tx.send(EngineRequest::new(vec![3, 4], 2,
                                   SamplerConfig::greedy(),
                                   Box::new(rtx)))
            .unwrap();
        drop(tx);
        let live = Arc::new(LiveStats::default());
        let opts = test_opts(64, 0);
        let stats = run_engine_opts(&backend, rx, &opts,
                                    Arc::new(AtomicBool::new(false)),
                                    &live)
            .unwrap();
        // B completed normally on the slot A abandoned
        let b = rrx.recv().unwrap();
        assert_eq!(b.tokens.len(), 2);
        assert!(!b.cancelled);
        assert_eq!(stats.tokens_out, 2);
        // A was retired after at most a couple of wasted tokens — the
        // closed sink is observed at the first emission and the very
        // next sweep frees the slot (one engine iteration of latency)
        assert_eq!(stats.cancelled, 1);
        assert!(stats.wasted_tokens >= 1 && stats.wasted_tokens <= 2,
                "wasted {} tokens before the slot was freed",
                stats.wasted_tokens);
        assert_eq!(live.cancelled.load(Ordering::SeqCst), 1);
        assert_eq!(live.wasted_tokens.load(Ordering::SeqCst),
                   stats.wasted_tokens);
        println!("cancel latency: slot freed after {} wasted tokens: ok",
                 stats.wasted_tokens);
    }

    #[test]
    fn cancel_flag_retires_a_queued_request_without_decoding() {
        // the flag is set while the request is still queued behind a
        // full batch: it must never reach a slot, and its Done reply is
        // cancelled with empty tokens
        let backend = tiny_backend(1);
        let (tx, rx) = channel::<EngineRequest>();
        let (rtx_a, rrx_a) = channel::<EngineResponse>();
        tx.send(EngineRequest::new(vec![1, 2], 3,
                                   SamplerConfig::greedy(),
                                   Box::new(rtx_a)))
            .unwrap();
        let flag = plain_flag();
        flag.store(true, Ordering::SeqCst); // cancelled before intake
        let (rtx_b, rrx_b) = channel::<EngineResponse>();
        tx.send(EngineRequest {
            prompt: vec![5, 6],
            max_new: 4,
            sampler: SamplerConfig::greedy(),
            submitted: Instant::now(),
            cancel: flag,
            sink: Box::new(rtx_b),
            cache: true,
        })
        .unwrap();
        drop(tx);
        let stats = run_engine(&backend, rx, Duration::from_micros(100),
                               Arc::new(AtomicBool::new(false)))
            .unwrap();
        let a = rrx_a.recv().unwrap();
        assert_eq!(a.tokens.len(), 3);
        assert!(!a.cancelled);
        let b = rrx_b.recv().unwrap();
        assert!(b.cancelled);
        assert!(b.tokens.is_empty());
        assert_eq!(stats.cancelled, 1);
        assert_eq!(stats.wasted_tokens, 0, "a queued cancel decodes nothing");
        assert_eq!(stats.tokens_out, 3);
    }

    #[test]
    fn prefix_cache_hit_reproduces_cold_tokens_and_reports_cached() {
        // two identical greedy requests, run back to back on the same
        // engine: the first prefills cold and seeds the cache, the
        // second full-hits the end-of-prefill snapshot, skips its whole
        // usable prefix, and MUST produce byte-identical tokens (the
        // restored snapshot IS the cold end-of-prefill state)
        let backend = tiny_backend(1);
        let prompt: Vec<i32> = (0..13).map(|i| (i * 3) % 16).collect();
        let (tx, rx) = channel::<EngineRequest>();
        let (rtx_a, rrx_a) = channel::<EngineResponse>();
        tx.send(EngineRequest::new(prompt.clone(), 4,
                                   SamplerConfig::greedy(),
                                   Box::new(rtx_a)))
            .unwrap();
        let (rtx_b, rrx_b) = channel::<EngineResponse>();
        tx.send(EngineRequest::new(prompt, 4, SamplerConfig::greedy(),
                                   Box::new(rtx_b)))
            .unwrap();
        drop(tx);
        let live = Arc::new(LiveStats::default());
        let opts = EngineOptions {
            prefix_cache_bytes: 1 << 20,
            ..test_opts(4, 0)
        };
        let stats = run_engine_opts(&backend, rx, &opts,
                                    Arc::new(AtomicBool::new(false)),
                                    &live)
            .unwrap();
        let a = rrx_a.recv().unwrap();
        let b = rrx_b.recv().unwrap();
        assert_eq!(a.tokens, b.tokens,
                   "cache-hit output must equal cold output");
        assert_eq!(a.tokens.len(), 4);
        assert_eq!(a.cached_tokens, 0, "first request prefills cold");
        assert!(b.cached_tokens > 0, "second request must hit");
        assert_eq!(stats.prefix_hits + stats.prefix_partial_hits, 1);
        assert_eq!(stats.prefix_misses, 1);
        assert_eq!(stats.prefix_cached_tokens, b.cached_tokens);
        assert!(stats.prefix_entries > 0);
        assert!(stats.prefix_bytes > 0);
        assert_eq!(live.prefix_misses.load(Ordering::SeqCst), 1);
        assert_eq!(live.prefix_cached_tokens.load(Ordering::SeqCst),
                   b.cached_tokens);
        println!("engine prefix-cache hit: {} tokens restored, \
                  tokens identical: ok", b.cached_tokens);
    }

    #[test]
    fn engine_options_round_cache_block_up_to_chunk_multiple() {
        let base = ServeConfig::default();
        // non-multiple block rounds UP to the next chunk multiple
        let cfg = ServeConfig {
            prefill_chunk: 8,
            prefix_cache_block: 12,
            ..base.clone()
        };
        assert_eq!(EngineOptions::from_serve(&cfg).prefix_cache_block, 16);
        // exact multiples pass through untouched
        let cfg = ServeConfig {
            prefill_chunk: 8,
            prefix_cache_block: 24,
            ..base.clone()
        };
        assert_eq!(EngineOptions::from_serve(&cfg).prefix_cache_block, 24);
        // 0 keeps its "use prefill_chunk" meaning
        let cfg = ServeConfig {
            prefill_chunk: 8,
            prefix_cache_block: 0,
            ..base.clone()
        };
        assert_eq!(EngineOptions::from_serve(&cfg).prefix_cache_block, 0);
        // legacy path (chunk <= 1): the block is never consulted, so it
        // passes through as-is
        let cfg = ServeConfig {
            prefill_chunk: 1,
            prefix_cache_block: 12,
            ..base
        };
        assert_eq!(EngineOptions::from_serve(&cfg).prefix_cache_block, 12);
    }

    #[test]
    fn fused_rounds_insert_one_snapshot_per_block_boundary() {
        // regression for the alignment-drift bug: a 4-block prompt
        // (chunk 4, usable prefix 15) must land one cache insert per
        // block boundary — cursors 4, 8, 12, then 15 at end of prefill.
        // Before the fix, the batched step between rounds bumped the
        // cursor once per iteration (5, 10, 15, ...), `cursor % block`
        // never fired after the first chunk, and only the end-of-prefill
        // snapshot survived.
        let backend = tiny_backend(1);
        let prompt: Vec<i32> = (0..16).map(|i| i % 16).collect();
        let (rx, rrx) = one_request(prompt, 1);
        let opts = EngineOptions {
            prefix_cache_bytes: 1 << 20,
            ..test_opts(4, 0)
        };
        let stats = run_engine_opts(&backend, rx, &opts,
                                    Arc::new(AtomicBool::new(false)),
                                    &Arc::new(LiveStats::default()))
            .unwrap();
        assert_eq!(rrx.recv().unwrap().tokens.len(), 1);
        // four fused rounds (4 + 4 + 4 + 3), one timing entry each
        assert_eq!(stats.prefill_tokens, 15);
        assert_eq!(stats.prefill_ms.len(), 4);
        // one entry per block boundary: prefixes 4, 8, 12 and the
        // end-of-prefill snapshot at 15
        assert_eq!(stats.prefix_entries, 4,
                   "expected one snapshot per block crossing");
        assert_eq!(stats.prefix_misses, 1);
    }

    /// Fails `prefill` on one designated slot — the engine-level fault
    /// isolation shape (the backend-level twin lives in backend.rs).
    struct FaultyPrefill(crate::runtime::backend::NativeBackend, usize);

    impl DecodeBackend for FaultyPrefill {
        fn batch(&self) -> usize {
            self.0.batch()
        }
        fn vocab(&self) -> usize {
            self.0.vocab()
        }
        fn kind(&self) -> &'static str {
            "faulty"
        }
        fn init_state(&self)
                      -> Result<crate::runtime::backend::DecodeState> {
            self.0.init_state()
        }
        fn step(&self, tokens: &IntTensor,
                state: &crate::runtime::backend::DecodeState)
                -> Result<(crate::tensor::Tensor,
                           crate::runtime::backend::DecodeState)> {
            self.0.step(tokens, state)
        }
        fn prefill_is_parallel(&self) -> bool {
            true
        }
        fn prefill(&self, tokens: &IntTensor, slot: usize,
                   state: &crate::runtime::backend::DecodeState)
                   -> Result<(crate::tensor::Tensor,
                              crate::runtime::backend::DecodeState)> {
            if slot == self.1 {
                anyhow::bail!("injected prefill fault on slot {slot}");
            }
            self.0.prefill(tokens, slot, state)
        }
    }

    #[test]
    fn failed_prefill_retires_only_the_offending_request() {
        // request A lands on the faulty slot: its prefill round errors,
        // it gets a terminal Failed event, and the engine KEEPS SERVING
        // — request B on the neighbouring lane completes normally.
        // Before the fix, `backend.prefill(...)?` killed the engine
        // thread and every in-flight request with it.
        let backend = FaultyPrefill(tiny_backend(2), 0);
        let (tx, rx) = channel::<EngineRequest>();
        let (etx, erx) = channel::<EngineEvent>();
        tx.send(EngineRequest::new((0..10).collect(), 2,
                                   SamplerConfig::greedy(),
                                   Box::new(etx)))
            .unwrap();
        let (rtx, rrx) = channel::<EngineResponse>();
        tx.send(EngineRequest::new(vec![1, 2, 3], 2,
                                   SamplerConfig::greedy(),
                                   Box::new(rtx)))
            .unwrap();
        drop(tx);
        let live = Arc::new(LiveStats::default());
        let opts = test_opts(8, 0);
        let stats = run_engine_opts(&backend, rx, &opts,
                                    Arc::new(AtomicBool::new(false)),
                                    &live)
            .unwrap();
        // B survived A's fault and completed on its own lane
        let b = rrx.recv().unwrap();
        assert_eq!(b.tokens.len(), 2);
        assert!(!b.cancelled);
        // A's stream: Started, then the terminal Failed — never Done
        let events: Vec<EngineEvent> = erx.iter().collect();
        assert!(matches!(events[0], EngineEvent::Started { .. }));
        let Some(EngineEvent::Failed { message }) = events.last() else {
            panic!("expected terminal Failed, got {:?}", events.last());
        };
        assert!(message.contains("injected prefill fault"),
                "message: {message}");
        assert_eq!(events.len(), 2);
        assert_eq!(stats.failed, 1);
        assert_eq!(stats.cancelled, 0);
        assert_eq!(stats.requests, 2);
        assert_eq!(stats.tokens_out, 2);
        assert_eq!(live.failed.load(Ordering::SeqCst), 1);
        println!("prefill fault isolation: engine survived, \
                  1 failed / 1 completed: ok");
    }

    #[test]
    fn mid_prefill_lanes_are_shielded_from_batched_steps() {
        // a long request's lane sits mid-prefill for several iterations
        // while a short request decodes: the pad its Idle lane is fed in
        // those mixed steps must not perturb its belief state (the
        // engine snapshots and restores shielded lanes around the step),
        // so its greedy tokens equal a solo run's exactly
        let long: Vec<i32> = (0..20).map(|i| (i * 5) % 16).collect();
        let solo = {
            let backend = tiny_backend(2);
            let (rx, rrx) = one_request(long.clone(), 4);
            let opts = test_opts(4, 0);
            run_engine_opts(&backend, rx, &opts,
                            Arc::new(AtomicBool::new(false)),
                            &Arc::new(LiveStats::default()))
                .unwrap();
            rrx.recv().unwrap().tokens
        };
        assert_eq!(solo.len(), 4);
        let backend = tiny_backend(2);
        let (tx, rx) = channel::<EngineRequest>();
        let (rtx_long, rrx_long) = channel::<EngineResponse>();
        tx.send(EngineRequest::new(long, 4, SamplerConfig::greedy(),
                                   Box::new(rtx_long)))
            .unwrap();
        let (rtx_short, rrx_short) = channel::<EngineResponse>();
        tx.send(EngineRequest::new(vec![1, 2], 6,
                                   SamplerConfig::greedy(),
                                   Box::new(rtx_short)))
            .unwrap();
        drop(tx);
        let opts = test_opts(4, 0);
        run_engine_opts(&backend, rx, &opts,
                        Arc::new(AtomicBool::new(false)),
                        &Arc::new(LiveStats::default()))
            .unwrap();
        assert_eq!(rrx_short.recv().unwrap().tokens.len(), 6);
        assert_eq!(rrx_long.recv().unwrap().tokens, solo,
                   "mid-prefill lane perturbed by interleaved decode");
    }

    #[test]
    fn unadmitted_request_counts_full_wait_as_queue_time() {
        let (tx, _rx) = channel::<EngineResponse>();
        let mut table = PendingTable::new();
        let t0 = Instant::now();
        table.submit(2, Box::new(tx), plain_flag(), t0);
        let finish = t0 + Duration::from_millis(7);
        let (_sink, queue_ms, total_ms, _cached) =
            table.finish(2, finish).unwrap();
        assert!((queue_ms - 7.0).abs() < 1e-6, "queue_ms {queue_ms}");
        assert!((total_ms - 7.0).abs() < 1e-6, "total_ms {total_ms}");
    }
}

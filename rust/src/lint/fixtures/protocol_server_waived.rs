//! Waived stand-in `serve/server.rs` for the `protocol-sync` pass:
//! one undocumented error code, suppressed by a waiver on its
//! emission line.  Never compiled — only `include_str!`-ed by
//! protocol_sync.rs tests.
//!
//! Codes:
//!
//! Event kinds: `err`.

fn reject(line: &str) -> Json {
    // lint: allow(protocol-sync, fixture: code documented in next PR)
    err_reply(None, "bad-json", line)
}

fn events() -> Vec<Json> {
    vec![Json::obj(vec![("event", Json::str("err"))])]
}

//! `repro-lint`: the repo's own static-analysis pass.
//!
//! Every invariant this repo trades on — the scan ≡ sequential-filter
//! identity, counter-based RNG determinism, bit-exact cache-hit ≡
//! cold-prefill parity, and the serve engine's fault-isolation rule —
//! is only as strong as the bug classes that keep re-breaking it:
//! silent `as`-cast token truncation, panics in the engine loop,
//! stats counters drifting between `EngineStats` / `LiveStats` / the
//! protocol reply / DESIGN.md, and undocumented `unsafe`.  `repro-lint`
//! tokenizes the repo's own Rust sources (see [`lexer`]) and enforces
//! those invariants as named, individually-testable passes:
//!
//! | pass              | invariant                                      |
//! |-------------------|------------------------------------------------|
//! | `panic`           | no `unwrap`/`expect`/`panic!`-family macros or |
//! |                   | unguarded indexing in serve hot paths          |
//! | `counter-sync`    | `EngineStats` ≡ `LiveStats` ≡ `{"cmd":"stats"}`|
//! |                   | reply ≡ server.rs doc ≡ DESIGN.md              |
//! | `protocol-sync`   | emitted err codes / event types ≡ protocol doc |
//! | `determinism`     | wall clocks, thread spawns, and narrowing `as` |
//! |                   | casts only where allowlisted                   |
//! | `unsafe`          | every `unsafe` carries a `// SAFETY:` comment  |
//! | `lock-order`      | the held-while-acquiring graph is acyclic,     |
//! |                   | agrees with the DESIGN.md §S19 rank table, and |
//! |                   | condvar waits recheck in a loop                |
//! | `send-sync-audit` | `unsafe impl Send/Sync` SAFETY comments argue  |
//! |                   | type + field + aliasing; no pub raw-ptr struct |
//! | `atomic-ordering` | `Relaxed` only on LiveStats counters; every    |
//! |                   | other ordering carries an `// ord:` rationale  |
//!
//! ## Waivers
//!
//! A finding is suppressed by a comment on the same line or the line
//! above, with a mandatory reason:
//!
//! ```text
//! // lint: allow(<pass>, <reason>)
//! ```
//!
//! Waivers are themselves audited: an empty reason, an unknown pass
//! name, or a *stale* waiver (one that suppresses nothing) is a
//! finding, so waivers cannot rot silently.
//!
//! Fixture files with known-bad snippets live under
//! `rust/src/lint/fixtures/` — they are `include_str!`-ed by each
//! pass's unit tests (never compiled as modules) and excluded from
//! the real-tree scan.  The binary front-end is
//! `rust/src/bin/repro_lint.rs`; CI runs it blocking and grep-pins
//! the per-pass result lines.

pub mod atomic_ordering;
pub mod counter_sync;
pub mod determinism;
pub mod lexer;
pub mod lock_order;
pub mod panic_free;
pub mod protocol_sync;
pub mod send_sync;
pub mod unsafe_audit;

use lexer::{lex, Tok, Token};
use std::fmt;
use std::path::Path;

/// Names of every pass, in report order.  Waiver comments must name
/// one of these.
pub const PASS_NAMES: [&str; 8] = [
    "panic",
    "counter-sync",
    "protocol-sync",
    "determinism",
    "unsafe",
    "lock-order",
    "send-sync-audit",
    "atomic-ordering",
];

/// One lint finding, anchored to a repo-relative path and 1-based line.
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    pub pass: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file, self.line, self.pass, self.message
        )
    }
}

/// A `// lint: allow(pass, reason)` waiver parsed from a comment.
#[derive(Debug, Clone)]
pub struct Waiver {
    pub pass: String,
    pub reason: String,
    pub line: usize,
}

/// One lexed source file, with `#[cfg(test)]` / `#[test]` regions
/// pre-computed so passes can restrict themselves to non-test code.
pub struct SourceFile {
    /// Repo-relative, '/'-separated path (e.g. `rust/src/serve/engine.rs`).
    pub path: String,
    /// Full token stream, comments included.
    pub toks: Vec<Token>,
    /// Code tokens only (comments stripped), for sequence matching.
    pub code: Vec<Token>,
    /// Raw source text (docs passes scan prose in module docs).
    pub src: String,
    test_ranges: Vec<(usize, usize)>,
}

impl SourceFile {
    /// Lex `src` as the file at `path` (repo-relative).
    pub fn from_source(path: &str, src: &str) -> SourceFile {
        let toks = lex(src);
        let code: Vec<Token> =
            toks.iter().filter(|t| !t.is_comment()).cloned().collect();
        let test_ranges = test_line_ranges(&code);
        SourceFile {
            path: path.to_string(),
            toks,
            code,
            src: src.to_string(),
            test_ranges,
        }
    }

    /// True if `line` falls inside a `#[cfg(test)]` / `#[test]` item.
    pub fn is_test_line(&self, line: usize) -> bool {
        self.test_ranges
            .iter()
            .any(|&(lo, hi)| line >= lo && line <= hi)
    }

    /// True if the path ends with the given '/'-separated suffix.
    pub fn path_ends_with(&self, suffix: &str) -> bool {
        self.path == suffix
            || self
                .path
                .strip_suffix(suffix)
                .is_some_and(|head| head.ends_with('/'))
    }

    /// The module doc (`//!` lines) joined with newlines.
    pub fn module_doc(&self) -> String {
        let mut doc = String::new();
        for t in &self.toks {
            if let Tok::LineComment(text) = &t.tok {
                if let Some(rest) = text.strip_prefix('!') {
                    doc.push_str(rest.strip_prefix(' ').unwrap_or(rest));
                    doc.push('\n');
                }
            }
        }
        doc
    }
}

/// Compute line ranges covered by `#[cfg(test)]`- or `#[test]`-gated
/// items, by scanning the comment-free token stream: on a test
/// attribute, skip any further attributes, then extend to the end of
/// the braced body (or to the terminating `;` for brace-less items).
fn test_line_ranges(code: &[Token]) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    let mut i = 0usize;
    while i < code.len() {
        if code[i].is_punct('#')
            && code.get(i + 1).is_some_and(|t| t.is_punct('['))
        {
            let (attr_end, is_test) = scan_attribute(code, i + 1);
            if is_test {
                let start_line = code[i].line;
                let end_line = item_end_line(code, attr_end);
                ranges.push((start_line, end_line));
                i = attr_end;
                continue;
            }
            i = attr_end;
            continue;
        }
        i += 1;
    }
    ranges
}

/// From the `[` at `open`, return (index one past the matching `]`,
/// whether the attribute gates test code).  `#[cfg(not(test))]` is
/// *not* a test gate.
fn scan_attribute(code: &[Token], open: usize) -> (usize, bool) {
    let mut depth = 0usize;
    let mut has_test = false;
    let mut has_not = false;
    let mut i = open;
    while i < code.len() {
        match &code[i].tok {
            Tok::Punct('[') => depth += 1,
            Tok::Punct(']') => {
                depth -= 1;
                if depth == 0 {
                    return (i + 1, has_test && !has_not);
                }
            }
            Tok::Ident(w) if w == "test" => has_test = true,
            Tok::Ident(w) if w == "not" => has_not = true,
            _ => {}
        }
        i += 1;
    }
    (code.len(), false)
}

/// Last line of the item starting after an attribute at `from`:
/// skip further attributes, then either brace-match the first `{`
/// or stop at a top-level `;`.
fn item_end_line(code: &[Token], mut from: usize) -> usize {
    // Skip stacked attributes.
    while from < code.len()
        && code[from].is_punct('#')
        && code.get(from + 1).is_some_and(|t| t.is_punct('['))
    {
        let (next, _) = scan_attribute(code, from + 1);
        from = next;
    }
    let mut depth = 0usize;
    let mut i = from;
    while i < code.len() {
        match &code[i].tok {
            Tok::Punct('{') => depth += 1,
            Tok::Punct('}') => {
                if depth <= 1 {
                    return code[i].line;
                }
                depth -= 1;
            }
            Tok::Punct(';') if depth == 0 => return code[i].line,
            _ => {}
        }
        i += 1;
    }
    code.last().map_or(0, |t| t.line)
}

/// Parse every `// lint: allow(pass, reason)` waiver in a file.
/// Waivers live in plain `//` comments only: doc comments (`///`,
/// `//!`) are prose *about* the waiver syntax, never a waiver.
pub fn parse_waivers(file: &SourceFile) -> Vec<Waiver> {
    let mut out = Vec::new();
    for t in &file.toks {
        let Some(text) = t.comment_text() else { continue };
        if text.starts_with('/') || text.starts_with('!') {
            continue; // doc comment
        }
        let Some(at) = text.find("lint:") else { continue };
        let rest = text[at + "lint:".len()..].trim_start();
        let Some(body) = rest.strip_prefix("allow(") else { continue };
        let Some(close) = body.rfind(')') else { continue };
        let inner = &body[..close];
        let (pass, reason) = match inner.find(',') {
            Some(comma) => (&inner[..comma], inner[comma + 1..].trim()),
            None => (inner, ""),
        };
        out.push(Waiver {
            pass: pass.trim().to_string(),
            reason: reason.to_string(),
            line: t.line,
        });
    }
    out
}

/// Everything a pass can look at.
pub struct LintInput {
    pub files: Vec<SourceFile>,
    /// DESIGN.md text ("" when absent — counter-sync then reports it).
    pub design_md: String,
}

/// Per-pass result line data.
#[derive(Debug, Clone)]
pub struct PassSummary {
    pub pass: &'static str,
    pub findings: usize,
    pub waivers_used: usize,
}

/// Full lint run result.
pub struct Report {
    /// Findings that survived waiver resolution (includes waiver-audit
    /// findings, which can never be waived).
    pub findings: Vec<Finding>,
    pub summaries: Vec<PassSummary>,
    pub files_scanned: usize,
}

impl Report {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Machine-readable form of the report, written by the binary
    /// front-end's `--json <file>` and uploaded as a CI artifact.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let passes = self
            .summaries
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("pass", Json::str(s.pass)),
                    ("findings", Json::num(s.findings as f64)),
                    ("waivers_used", Json::num(s.waivers_used as f64)),
                ])
            })
            .collect();
        let findings = self
            .findings
            .iter()
            .map(|f| {
                Json::obj(vec![
                    ("pass", Json::str(f.pass)),
                    ("file", Json::str(&f.file)),
                    ("line", Json::num(f.line as f64)),
                    ("message", Json::str(&f.message)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("clean", Json::Bool(self.is_clean())),
            ("files_scanned", Json::num(self.files_scanned as f64)),
            ("passes", Json::Arr(passes)),
            ("findings", Json::Arr(findings)),
        ])
    }

    /// Render the per-pass result lines CI grep-pins, then findings.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for s in &self.summaries {
            out.push_str(&format!(
                "repro-lint[{}]: {} findings, {} waivers used\n",
                s.pass, s.findings, s.waivers_used
            ));
        }
        for f in &self.findings {
            out.push_str(&format!("{f}\n"));
        }
        out.push_str(&format!(
            "repro-lint: {} ({} files scanned)\n",
            if self.is_clean() { "clean" } else { "DIRTY" },
            self.files_scanned
        ));
        out
    }
}

/// Run every pass over `input`, resolve waivers, and audit the
/// waivers themselves.
pub fn run(input: &LintInput) -> Report {
    run_filtered(input, None)
}

/// Like [`run`], restricted to a single pass when `only` is given
/// (the front-end's `--pass`).  Waivers for non-selected passes stay
/// out of the stale audit — a `--pass panic` run must not report
/// another pass's (unexercised) waivers as stale — but unknown-pass
/// waivers are always reported: they are wrong in every run.
pub fn run_filtered(input: &LintInput, only: Option<&str>) -> Report {
    let selected = |name: &str| only.is_none_or(|o| o == name);
    let passes: [fn(&LintInput) -> Vec<Finding>; 8] = [
        panic_free::run,
        counter_sync::run,
        protocol_sync::run,
        determinism::run,
        unsafe_audit::run,
        lock_order::run,
        send_sync::run,
        atomic_ordering::run,
    ];
    let raw: Vec<(usize, Vec<Finding>)> = passes
        .iter()
        .enumerate()
        .filter(|(i, _)| selected(PASS_NAMES[*i]))
        .map(|(i, p)| (i, p(input)))
        .collect();

    // Waivers per file, each with a used flag.
    let mut waivers: Vec<(usize, Waiver, bool)> = Vec::new();
    for (fi, file) in input.files.iter().enumerate() {
        for w in parse_waivers(file) {
            if selected(w.pass.as_str())
                || !PASS_NAMES.contains(&w.pass.as_str())
            {
                waivers.push((fi, w, false));
            }
        }
    }

    let mut findings = Vec::new();
    let mut summaries = Vec::new();
    for (pass_idx, pass_findings) in raw {
        let pass = PASS_NAMES[pass_idx];
        let mut kept = 0usize;
        let mut used = 0usize;
        for f in pass_findings {
            let fi = input.files.iter().position(|sf| sf.path == f.file);
            let waived = fi.is_some_and(|fi| {
                waivers.iter_mut().any(|(wfi, w, w_used)| {
                    let covers =
                        w.line == f.line || w.line + 1 == f.line;
                    if *wfi == fi && w.pass == pass && covers {
                        *w_used = true;
                        true
                    } else {
                        false
                    }
                })
            });
            if waived {
                used += 1;
            } else {
                kept += 1;
                findings.push(f);
            }
        }
        summaries.push(PassSummary { pass, findings: kept, waivers_used: used });
    }

    // Waiver audit: unknown pass, empty reason, or stale (unused).
    for (fi, w, used) in &waivers {
        let file = &input.files[*fi].path;
        if !PASS_NAMES.contains(&w.pass.as_str()) {
            findings.push(Finding {
                pass: "waiver",
                file: file.clone(),
                line: w.line,
                message: format!(
                    "waiver names unknown pass `{}` (known: {})",
                    w.pass,
                    PASS_NAMES.join(", ")
                ),
            });
        } else if w.reason.is_empty() {
            findings.push(Finding {
                pass: "waiver",
                file: file.clone(),
                line: w.line,
                message: format!(
                    "waiver for `{}` has no reason; use \
                     `// lint: allow({}, <why>)`",
                    w.pass, w.pass
                ),
            });
        } else if !*used {
            findings.push(Finding {
                pass: "waiver",
                file: file.clone(),
                line: w.line,
                message: format!(
                    "stale waiver: no `{}` finding on this or the next \
                     line — remove it",
                    w.pass
                ),
            });
        }
    }

    Report { findings, summaries, files_scanned: input.files.len() }
}

/// Load the repo tree rooted at `root` (the directory holding
/// `Cargo.toml`) and run the lint: every `.rs` under `rust/src`
/// except the lint fixtures, plus `DESIGN.md` for the doc-sync
/// checks.
pub fn run_repo(root: &Path) -> std::io::Result<Report> {
    run_repo_filtered(root, None)
}

/// [`run_repo`] restricted to one pass (the front-end's `--pass`).
pub fn run_repo_filtered(
    root: &Path,
    only: Option<&str>,
) -> std::io::Result<Report> {
    let mut paths = Vec::new();
    collect_rs(&root.join("rust").join("src"), &mut paths)?;
    paths.sort();
    let mut files = Vec::new();
    for p in paths {
        let rel = p
            .strip_prefix(root)
            .unwrap_or(&p)
            .to_string_lossy()
            .replace('\\', "/");
        if rel.contains("lint/fixtures/") {
            continue;
        }
        let src = std::fs::read_to_string(&p)?;
        files.push(SourceFile::from_source(&rel, &src));
    }
    let design_md = std::fs::read_to_string(root.join("DESIGN.md"))
        .unwrap_or_default();
    Ok(run_filtered(&LintInput { files, design_md }, only))
}

fn collect_rs(
    dir: &Path,
    out: &mut Vec<std::path::PathBuf>,
) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn file(path: &str, src: &str) -> SourceFile {
        SourceFile::from_source(path, src)
    }

    #[test]
    fn cfg_test_regions_cover_test_mod_only() {
        let src = "\
fn hot() {}\n\
#[cfg(test)]\n\
mod tests {\n\
    #[test]\n\
    fn t() { assert!(true); }\n\
}\n\
fn also_hot() {}\n";
        let f = file("rust/src/serve/engine.rs", src);
        assert!(!f.is_test_line(1));
        assert!(f.is_test_line(2));
        assert!(f.is_test_line(5));
        assert!(f.is_test_line(6));
        assert!(!f.is_test_line(7));
    }

    #[test]
    fn cfg_not_test_is_not_a_test_region() {
        let src = "#[cfg(not(test))]\nfn prod() { body(); }\n";
        let f = file("rust/src/serve/engine.rs", src);
        assert!(!f.is_test_line(2));
    }

    #[test]
    fn braceless_cfg_test_item_covers_one_statement() {
        let src = "#[cfg(test)]\nuse crate::testing::Helper;\nfn f() {}\n";
        let f = file("rust/src/serve/engine.rs", src);
        assert!(f.is_test_line(2));
        assert!(!f.is_test_line(3));
    }

    #[test]
    fn waiver_parse_extracts_pass_and_reason() {
        let f = file(
            "rust/src/serve/engine.rs",
            "// lint: allow(panic, cursor <= prompt.len() by admit)\n\
             let x = v[0];\n",
        );
        let ws = parse_waivers(&f);
        assert_eq!(ws.len(), 1);
        assert_eq!(ws[0].pass, "panic");
        assert_eq!(ws[0].reason, "cursor <= prompt.len() by admit");
        assert_eq!(ws[0].line, 1);
    }

    #[test]
    fn waiver_reason_may_contain_parens() {
        let f = file(
            "rust/src/serve/engine.rs",
            "x(); // lint: allow(determinism, debug meter (env-gated))\n",
        );
        let ws = parse_waivers(&f);
        assert_eq!(ws[0].reason, "debug meter (env-gated)");
    }

    #[test]
    fn stale_waiver_is_reported() {
        let input = LintInput {
            files: vec![file(
                "rust/src/serve/engine.rs",
                "// lint: allow(panic, nothing here panics)\nfn ok() {}\n",
            )],
            design_md: String::new(),
        };
        let report = run(&input);
        assert_eq!(report.findings.len(), 1);
        assert_eq!(report.findings[0].pass, "waiver");
        assert!(report.findings[0].message.contains("stale"));
    }

    #[test]
    fn waiver_without_reason_is_reported() {
        let input = LintInput {
            files: vec![file(
                "rust/src/serve/engine.rs",
                "let x = v[0]; // lint: allow(panic)\n",
            )],
            design_md: String::new(),
        };
        let report = run(&input);
        assert!(report
            .findings
            .iter()
            .any(|f| f.pass == "waiver" && f.message.contains("no reason")));
    }

    #[test]
    fn waiver_with_unknown_pass_is_reported() {
        let input = LintInput {
            files: vec![file(
                "rust/src/serve/engine.rs",
                "fn f() {} // lint: allow(panics, typo in pass name)\n",
            )],
            design_md: String::new(),
        };
        let report = run(&input);
        assert!(report
            .findings
            .iter()
            .any(|f| f.pass == "waiver" && f.message.contains("unknown pass")));
    }

    #[test]
    fn waiver_on_preceding_line_suppresses_and_counts_used() {
        let src = "\
fn hot(v: &[i32]) -> i32 {\n\
    // lint: allow(panic, fixture: index is bounds-checked by caller)\n\
    v[0]\n\
}\n";
        let input = LintInput {
            files: vec![file("rust/src/serve/engine.rs", src)],
            design_md: String::new(),
        };
        let report = run(&input);
        assert!(
            report.findings.is_empty(),
            "unexpected findings: {:?}",
            report.findings
        );
        let panic_summary = report
            .summaries
            .iter()
            .find(|s| s.pass == "panic")
            .expect("panic pass summary");
        assert_eq!(panic_summary.waivers_used, 1);
    }

    #[test]
    fn doc_comments_are_never_parsed_as_waivers() {
        // DESIGN.md §S18 and the lint module docs QUOTE the waiver
        // syntax in `///` / `//!` comments; quoting it must not mint a
        // waiver (nor trip the unknown-pass/stale audits).
        let f = file(
            "rust/src/serve/engine.rs",
            "//! lint: allow(panic, module doc quoting the syntax)\n\
             /// lint: allow(<pass>, <reason>)\n\
             fn documented() {}\n",
        );
        assert!(parse_waivers(&f).is_empty());
        let input = LintInput { files: vec![f], design_md: String::new() };
        let report = run(&input);
        assert!(
            report.findings.is_empty(),
            "doc comments audited as waivers: {:?}",
            report.findings
        );
    }

    #[test]
    fn filtered_run_reports_only_the_selected_pass() {
        // a panic finding AND a foreign-pass waiver that would be
        // stale in a full run — the filtered run must see neither the
        // other passes' summaries nor that waiver
        let src = "\
fn hot(v: &[i32]) -> i32 {\n\
    // lint: allow(determinism, not exercised in a --pass panic run)\n\
    v[0]\n\
}\n";
        let input = LintInput {
            files: vec![file("rust/src/serve/engine.rs", src)],
            design_md: String::new(),
        };
        let report = run_filtered(&input, Some("panic"));
        assert_eq!(report.summaries.len(), 1);
        assert_eq!(report.summaries[0].pass, "panic");
        assert_eq!(report.findings.len(), 1, "{:?}", report.findings);
        assert_eq!(report.findings[0].pass, "panic");
        // the full run DOES report that waiver as stale
        let full = run(&input);
        assert!(
            full.findings
                .iter()
                .any(|f| f.pass == "waiver" && f.message.contains("stale")),
            "{:?}",
            full.findings
        );
    }

    #[test]
    fn filtered_run_still_reports_unknown_pass_waivers() {
        let input = LintInput {
            files: vec![file(
                "rust/src/serve/engine.rs",
                "fn f() {} // lint: allow(panics, typo'd pass name)\n",
            )],
            design_md: String::new(),
        };
        let report = run_filtered(&input, Some("unsafe"));
        assert!(report
            .findings
            .iter()
            .any(|f| f.pass == "waiver" && f.message.contains("unknown pass")));
    }

    #[test]
    fn report_json_round_trips_through_the_repo_parser() {
        let input = LintInput {
            files: vec![file(
                "rust/src/serve/engine.rs",
                "fn hot(v: &[i32]) -> i32 { v[0] }\n",
            )],
            design_md: String::new(),
        };
        let report = run(&input);
        let parsed = crate::util::json::parse(&report.to_json().to_pretty())
            .expect("report JSON parses");
        assert_eq!(
            parsed.req("clean").and_then(|v| v.as_bool()).ok(),
            Some(false)
        );
        let passes = parsed
            .req("passes")
            .and_then(|v| v.as_arr())
            .expect("passes array");
        assert_eq!(passes.len(), PASS_NAMES.len());
        let findings = parsed
            .req("findings")
            .and_then(|v| v.as_arr())
            .expect("findings array");
        assert!(!findings.is_empty());
    }

    // The teeth of the whole PR: `cargo test` re-runs the lint over
    // the real tree, so a finding introduced by any future change
    // fails tier-1 even before the CI repro-lint step runs.
    #[test]
    fn real_tree_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let report = run_repo(root).expect("lint walk failed");
        assert!(
            report.is_clean(),
            "repro-lint findings on the real tree:\n{}",
            report.render()
        );
    }
}

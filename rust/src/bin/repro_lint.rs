//! `repro-lint` front-end: run the repo's static-analysis passes over
//! the tree and exit non-zero on any finding (stale waivers included).
//!
//! Usage:
//!
//! ```text
//! cargo run --bin repro_lint --                 # lint this repo
//! cargo run --bin repro_lint -- <root>          # lint a checkout
//! cargo run --bin repro_lint -- --pass <name>   # one pass only
//! cargo run --bin repro_lint -- --json <file>   # also write the
//!                                               # machine-readable
//!                                               # report (CI artifact)
//! ```
//!
//! Output is the per-pass result lines CI grep-pins
//! (`repro-lint[<pass>]: N findings, M waivers used`), each surviving
//! finding as `path:line: [pass] message`, and a final
//! `repro-lint: clean (N files scanned)` / `repro-lint: DIRTY (..)`
//! verdict.  The `--json` report is written whether the tree is clean
//! or dirty, so CI uploads it either way.  See `rust/src/lint/mod.rs`
//! and DESIGN.md §S18 for the pass and waiver semantics.

use std::path::PathBuf;
use std::process::ExitCode;

fn usage(err: &str) -> ExitCode {
    eprintln!("repro-lint: {err}");
    eprintln!(
        "usage: repro_lint [<root>] [--pass <name>] [--json <file>]"
    );
    ExitCode::from(2)
}

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut json_path: Option<PathBuf> = None;
    let mut only: Option<String> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--json" => match args.next() {
                Some(p) => json_path = Some(PathBuf::from(p)),
                None => return usage("--json needs a file path"),
            },
            "--pass" => match args.next() {
                Some(p) => only = Some(p),
                None => return usage("--pass needs a pass name"),
            },
            _ if a.starts_with("--") => {
                return usage(&format!("unknown flag {a:?}"));
            }
            _ if root.is_none() => root = Some(PathBuf::from(a)),
            _ => return usage(&format!("unexpected argument {a:?}")),
        }
    }
    if let Some(p) = &only {
        if !kla::lint::PASS_NAMES.contains(&p.as_str()) {
            return usage(&format!(
                "unknown pass {p:?} (known: {})",
                kla::lint::PASS_NAMES.join(", ")
            ));
        }
    }
    let root = root
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")));
    let report =
        match kla::lint::run_repo_filtered(&root, only.as_deref()) {
            Ok(r) => r,
            Err(e) => {
                eprintln!(
                    "repro-lint: cannot scan {}: {e}",
                    root.display()
                );
                return ExitCode::from(2);
            }
        };
    print!("{}", report.render());
    if let Some(path) = json_path {
        if let Err(e) =
            std::fs::write(&path, report.to_json().to_pretty())
        {
            eprintln!(
                "repro-lint: cannot write {}: {e}",
                path.display()
            );
            return ExitCode::from(2);
        }
    }
    if report.is_clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

"""Pure-jnp sequential oracle for the KLA information filter.

This is the CORE correctness signal of the repository: a direct, step-by-step
transcription of the paper's information-form Kalman recursions
(Theorem 1 / Theorem 2), with no scan tricks.  Every other implementation
(the `lax.associative_scan` formulation, the Pallas kernel, and the native
Rust implementations) is validated against this file.

Recursions (diagonal model, all ops elementwise over a state of shape (N, D)):

    phi_t   = k_t^2 * lam_v_t                      (outer product over (N, D))
    rho_t   = 1 / (abar^2 + pbar * lam_{t-1})
    lam_t   = rho_t * lam_{t-1} + phi_t            (Moebius precision, Eq. 18)
    f_t     = rho_t * abar                         (history-dependent forget gate)
    eta_t   = f_t * eta_{t-1} + k_t * (lam_v_t * v_t)   (information mean, Eq. 19)
    mu_t    = eta_t / lam_t
    y_t     = q_t^T mu_t                           (readout, Eq. 11)

Shapes (single sequence; the batched wrapper vmaps over B):
    k:     (T, N)    observation operator (shared across channels)
    q:     (T, N)    readout operator
    v:     (T, D)    token evidence
    lam_v: (T, D)    value precision (> 0)
    abar:  (N, D)    discretised OU decay, in (0, 1)
    pbar:  (N, D)    discretised OU process noise, >= 0
    lam0:  (N, D)    initial posterior precision (> 0)
    eta0:  (N, D)    initial information mean
Returns:
    lam: (T, N, D), eta: (T, N, D), y: (T, D)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

LAM_MIN = 1e-6
LAM_MAX = 1e8


def kla_filter_ref(k, q, v, lam_v, abar, pbar, lam0, eta0):
    """Sequential information filter via `lax.scan` (still the oracle: the
    per-step body is the literal textbook recursion; scan is only used to
    stay jittable)."""
    abar2 = abar * abar

    def step(carry, inputs):
        lam_prev, eta_prev = carry
        k_t, v_t, lv_t = inputs
        phi_t = (k_t[:, None] ** 2) * lv_t[None, :]          # (N, D)
        rho_t = 1.0 / (abar2 + pbar * lam_prev)              # (N, D)
        lam_t = jnp.clip(rho_t * lam_prev + phi_t, LAM_MIN, LAM_MAX)
        f_t = rho_t * abar
        eta_t = f_t * eta_prev + k_t[:, None] * (lv_t * v_t)[None, :]
        return (lam_t, eta_t), (lam_t, eta_t)

    (_, _), (lam, eta) = jax.lax.scan(step, (lam0, eta0), (k, v, lam_v))
    mu = eta / lam                                           # (T, N, D)
    y = jnp.einsum("tn,tnd->td", q, mu)
    return lam, eta, y


def kla_filter_ref_python(k, q, v, lam_v, abar, pbar, lam0, eta0):
    """Plain-Python loop (no lax at all) — the oracle's oracle.  Used only
    in tests at tiny sizes to rule out a shared bug in the scan machinery."""
    import numpy as np

    k, q, v, lam_v = map(np.asarray, (k, q, v, lam_v))
    abar, pbar = np.asarray(abar), np.asarray(pbar)
    lam_prev, eta_prev = np.asarray(lam0).copy(), np.asarray(eta0).copy()
    T = k.shape[0]
    lam_out, eta_out, y_out = [], [], []
    for t in range(T):
        phi = (k[t][:, None] ** 2) * lam_v[t][None, :]
        rho = 1.0 / (abar * abar + pbar * lam_prev)
        lam_t = np.clip(rho * lam_prev + phi, LAM_MIN, LAM_MAX)
        f = rho * abar
        eta_t = f * eta_prev + k[t][:, None] * (lam_v[t] * v[t])[None, :]
        lam_out.append(lam_t)
        eta_out.append(eta_t)
        y_out.append(q[t] @ (eta_t / lam_t))
        lam_prev, eta_prev = lam_t, eta_t
    import numpy as np
    return (np.stack(lam_out), np.stack(eta_out), np.stack(y_out))


def kla_filter_ref_batched(k, q, v, lam_v, abar, pbar, lam0, eta0):
    """vmap the oracle over a leading batch dimension.

    k, q: (B, T, N); v, lam_v: (B, T, D); abar/pbar/lam0/eta0: (N, D).
    """
    fn = jax.vmap(kla_filter_ref, in_axes=(0, 0, 0, 0, None, None, None, None))
    return fn(k, q, v, lam_v, abar, pbar, lam0, eta0)


def kla_posterior_moments(lam, eta, q):
    """Posterior mean/variance readouts used by the probabilistic decoding
    path (KLA+) and the Fig. 5b variance diagnostics.

    y_mu[t]  = q_t^T (eta_t / lam_t)            (paper Eq. 11)
    y_var[t] = (q_t^2)^T (1 / lam_t)            (Alg. 1 'Decode Variance')
    """
    mu = eta / lam
    y_mu = jnp.einsum("...tn,...tnd->...td", q, mu)
    y_var = jnp.einsum("...tn,...tnd->...td", q * q, 1.0 / lam)
    return y_mu, y_var

//! TCP front-end: newline-delimited JSON over a plain socket (std::net —
//! no tokio offline).  One reader thread per connection; all generation
//! funnels into the single engine thread (continuous batching).
//!
//! Protocol (one JSON object per line):
//!   -> {"prompt": [1,2,3], "max_new_tokens": 8}
//!   <- {"tokens": [...], "total_ms": 12.3, "queue_ms": 0.1,
//!       "uncertainty": 0.42}
//!   -> {"cmd": "stats"}    <- {"requests": N, "steps": N,
//!       "tokens_out": N, "prefill_tokens": N}   (live counters)
//!   -> {"cmd": "shutdown"} <- {"ok": true}    (stops the listener —
//!       the handler pokes the accept loop itself, no external
//!       connection needed for the server to quiesce)

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::time::Instant;

use std::path::PathBuf;

use anyhow::{Context, Result};

use super::engine::{run_engine_opts, EngineOptions, EngineRequest,
                    EngineStats, LiveStats};
use crate::config::ServeConfig;
use crate::runtime::backend::NativeBackend;
use crate::runtime::{Runtime, Value};
use crate::util::Json;

pub struct ServerHandle {
    pub addr: String,
    shutdown: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<Result<EngineStats>>>,
    listener_join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Signal shutdown and collect engine stats.
    pub fn stop(mut self) -> Result<EngineStats> {
        self.shutdown.store(true, Ordering::SeqCst);
        // poke the listener so accept() returns
        let _ = TcpStream::connect(&self.addr);
        if let Some(j) = self.listener_join.take() {
            let _ = j.join();
        }
        match self.join.take() {
            Some(j) => j.join().expect("engine thread panicked"),
            None => Ok(EngineStats::default()),
        }
    }
}

/// Which decode backend the engine thread should build.
///
/// PJRT handles are not Send, so the XLA variant carries plain data
/// (artifact dir + base + params) and the engine thread builds its own
/// Runtime and DecodeSession; the native variant is plain data already
/// and moves straight into the engine thread.
pub enum EngineSpec {
    /// XLA/PJRT over a `{base}_decode` artifact (needs `make artifacts`).
    Xla {
        artifacts_dir: PathBuf,
        artifact: String,
        params: Vec<Value>,
    },
    /// Pure-Rust KLA model — no artifacts required.
    Native(NativeBackend),
}

impl EngineSpec {
    fn kind(&self) -> &'static str {
        match self {
            EngineSpec::Xla { .. } => "xla",
            EngineSpec::Native(_) => "native",
        }
    }
}

/// Start the server on the XLA artifact backend; returns once the socket
/// is listening.  (Kept as the historical entry point — thin wrapper
/// over [`serve_with`].)
pub fn serve(artifacts_dir: PathBuf, artifact_base: String,
             params: Vec<Value>, cfg: &ServeConfig) -> Result<ServerHandle> {
    serve_with(EngineSpec::Xla {
        artifacts_dir,
        artifact: artifact_base,
        params,
    }, cfg)
}

/// Start the server on the pure-Rust native backend — the offline path:
/// no artifacts, no PJRT, same engine/batcher/cache stack.
pub fn serve_native(backend: NativeBackend, cfg: &ServeConfig)
                    -> Result<ServerHandle> {
    serve_with(EngineSpec::Native(backend), cfg)
}

/// Start the server over any [`EngineSpec`]; returns once the socket is
/// listening.
pub fn serve_with(spec: EngineSpec, cfg: &ServeConfig)
                  -> Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding {}", cfg.addr))?;
    let addr = listener.local_addr()?.to_string();
    let (tx, rx) = channel::<EngineRequest>();
    let opts = EngineOptions::from_serve(cfg);
    let shutdown = Arc::new(AtomicBool::new(false));
    let live = Arc::new(LiveStats::default());
    let shutdown_engine = shutdown.clone();
    let live_engine = live.clone();
    let backend_kind = spec.kind();
    let engine_join = std::thread::spawn(move || match spec {
        EngineSpec::Xla { artifacts_dir, artifact, params } => {
            let rt = Runtime::new(&artifacts_dir)?;
            let session = crate::runtime::DecodeSession::new(
                &rt, &artifact, params)?;
            run_engine_opts(&session, rx, &opts, shutdown_engine,
                            &live_engine)
        }
        EngineSpec::Native(backend) => {
            run_engine_opts(&backend, rx, &opts, shutdown_engine,
                            &live_engine)
        }
    });

    let shutdown2 = shutdown.clone();
    let max_new = cfg.max_new_tokens;
    let self_addr = addr.clone();
    let listener_join = std::thread::spawn(move || {
        for stream in listener.incoming() {
            if shutdown2.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let tx = tx.clone();
            let shutdown3 = shutdown2.clone();
            let live3 = live.clone();
            let addr3 = self_addr.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(stream, tx, max_new, shutdown3,
                                    live3, addr3);
            });
        }
        // tx (and all clones in finished handlers) dropping closes the
        // engine's queue, letting run_engine drain and exit.
    });

    crate::log_info!("serving on {addr} ({backend_kind} backend)");
    Ok(ServerHandle {
        addr,
        shutdown,
        join: Some(engine_join),
        listener_join: Some(listener_join),
    })
}

fn handle_conn(stream: TcpStream, tx: Sender<EngineRequest>,
               default_max_new: usize, shutdown: Arc<AtomicBool>,
               live: Arc<LiveStats>, self_addr: String)
               -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match handle_line(&line, &tx, default_max_new,
                                      &shutdown, &live, &self_addr) {
            Ok(json) => json,
            Err(e) => Json::obj(vec![("error", Json::str(&e.to_string()))]),
        };
        writer.write_all(reply.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
    }
    crate::log_debug!("connection {peer:?} closed");
    Ok(())
}

fn handle_line(line: &str, tx: &Sender<EngineRequest>,
               default_max_new: usize, shutdown: &AtomicBool,
               live: &LiveStats, self_addr: &str) -> Result<Json> {
    let req = crate::util::json::parse(line)?;
    if let Some(cmd) = req.get("cmd") {
        match cmd.as_str()? {
            "shutdown" => {
                shutdown.store(true, Ordering::SeqCst);
                // poke our own accept() so the listener observes the
                // flag and exits — without this, a client-issued
                // shutdown left the listener thread blocked until some
                // EXTERNAL connection happened to arrive
                let _ = TcpStream::connect(self_addr);
                return Ok(Json::obj(vec![("ok", Json::Bool(true))]));
            }
            "ping" => return Ok(Json::obj(vec![("ok", Json::Bool(true))])),
            "stats" => {
                let n = |v: usize| Json::num(v as f64);
                return Ok(Json::obj(vec![
                    ("requests", n(live.requests.load(Ordering::Relaxed))),
                    ("steps", n(live.steps.load(Ordering::Relaxed))),
                    ("tokens_out",
                     n(live.tokens_out.load(Ordering::Relaxed))),
                    ("prefill_tokens",
                     n(live.prefill_tokens.load(Ordering::Relaxed))),
                ]));
            }
            other => anyhow::bail!("unknown cmd {other:?}"),
        }
    }
    let prompt: Vec<i32> = req
        .req("prompt")?
        .as_arr()?
        .iter()
        .map(|x| Ok(x.as_i64()? as i32))
        .collect::<Result<_>>()?;
    let max_new = req
        .get("max_new_tokens")
        .and_then(|x| x.as_usize().ok())
        .unwrap_or(default_max_new);
    let (rtx, rrx) = channel();
    tx.send(EngineRequest {
        prompt,
        max_new,
        submitted: Instant::now(),
        resp: rtx,
    })
    .map_err(|_| anyhow::anyhow!("engine is shut down"))?;
    let resp = rrx
        .recv()
        .map_err(|_| anyhow::anyhow!("engine dropped the request"))?;
    Ok(Json::obj(vec![
        ("tokens",
         Json::Arr(resp.tokens.iter().map(|&t| Json::num(t as f64))
             .collect())),
        ("queue_ms", Json::num(resp.queue_ms)),
        ("total_ms", Json::num(resp.total_ms)),
        ("uncertainty", Json::num(resp.uncertainty as f64)),
    ]))
}

/// Minimal blocking client (used by tests, the serve_demo example and the
/// throughput bench).
pub struct Client {
    stream: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client { stream: BufReader::new(stream) })
    }

    pub fn request(&mut self, prompt: &[i32], max_new: usize)
                   -> Result<Json> {
        let req = Json::obj(vec![
            ("prompt",
             Json::Arr(prompt.iter().map(|&t| Json::num(t as f64))
                 .collect())),
            ("max_new_tokens", Json::num(max_new as f64)),
        ]);
        self.send_line(&req.to_string())
    }

    pub fn ping(&mut self) -> Result<Json> {
        self.send_line(r#"{"cmd":"ping"}"#)
    }

    /// Live engine counters: requests, steps, tokens_out,
    /// prefill_tokens — answered mid-serve, not only after shutdown.
    pub fn stats(&mut self) -> Result<Json> {
        self.send_line(r#"{"cmd":"stats"}"#)
    }

    pub fn shutdown(&mut self) -> Result<Json> {
        self.send_line(r#"{"cmd":"shutdown"}"#)
    }

    fn send_line(&mut self, line: &str) -> Result<Json> {
        let stream = self.stream.get_mut();
        stream.write_all(line.as_bytes())?;
        stream.write_all(b"\n")?;
        stream.flush()?;
        let mut reply = String::new();
        self.stream.read_line(&mut reply)?;
        crate::util::json::parse(reply.trim())
    }
}

fn main() { println!("todo"); }

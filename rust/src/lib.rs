//! # kla — Kalman Linear Attention, reproduced as a Rust+JAX+Pallas stack
//!
//! Three layers (DESIGN.md):
//! - **L1/L2** live in `python/compile/` and are AOT-lowered to HLO text
//!   under `artifacts/` at build time (`make artifacts`);
//! - **L3** is this crate: runtime (PJRT), data pipeline, trainer,
//!   evaluation, serving, native KLA kernels, and the benchmark harness.
//!
//! Python never runs on the request path; after artifacts are built the
//! `repro` binary is self-contained.
//!
//! ## The `kla::api` surface
//!
//! All native scans — the KLA information filter and the GLA baseline,
//! at train-time (full-sequence) and decode-time (per-token) granularity
//! — go through one abstraction, [`api::Filter`]:
//!
//! ```ignore
//! use kla::api::{Filter, KlaFilter, ScanPlan};
//!
//! let belief = KlaFilter::init(&params);                  // prior
//! let (out, posterior) = KlaFilter::prefix(              // full scan
//!     &params, &inputs, &belief, &ScanPlan::chunked(8));
//! let mut carry = posterior.clone();                     // decode-time
//! let y_next = KlaFilter::step(&params, &next_inputs, 0, &mut carry);
//! ```
//!
//! Execution strategy (sequential / Blelloch tree / chunked multi-core /
//! auto) and the batch dimension are selected by [`api::ScanPlan`];
//! batched `(B, T, …)` work goes through [`api::prefix_batch`].  The
//! serving engine carries uncertainty in the same belief type
//! ([`api::KlaBelief`]) the training-side scan produces.  See
//! `DESIGN.md` §API for the design and the migration table from the old
//! free-function entry points, and `rust/tests/conformance_api.rs` for
//! the laws every implementation must satisfy.
//!
//! ## Decode backends
//!
//! Serving is generic over [`runtime::backend::DecodeBackend`]: the
//! pure-Rust [`runtime::NativeBackend`] (over [`kla::NativeLm`]) runs the
//! whole engine/batcher/belief-cache stack with no XLA artifacts, while
//! [`runtime::DecodeSession`] is the PJRT implementation of the same
//! seam.  See `DESIGN.md` §S17 for the backend matrix and per-backend
//! test coverage.

#![deny(unsafe_op_in_unsafe_fn)]

pub mod api;
pub mod baselines;
pub mod bench;
pub mod cli;
pub mod config;
pub mod data;
pub mod eval;
pub mod kla;
pub mod lint;
pub mod mc;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod testing;
pub mod train;
pub mod util;

pub use tensor::{IntTensor, Tensor};

//! A5 permutation-composition state tracking (paper Fig. 1a).
//!
//!   cargo run --release --example a5_tracking [steps] [models] [depths]
//!
//! Trains each (model, depth) on the A5 word problem and reports accuracy;
//! the paper's claim: KLA solves it at depth 1-2 where linear mixers
//! (mamba/gla) and attention (gpt) need depth growing with length.

use anyhow::Result;
use kla::config::TrainConfig;
use kla::data::task_by_name;
use kla::runtime::Runtime;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(400);
    let models: Vec<String> = args
        .get(2)
        .map(|s| s.split(',').map(|x| x.to_string()).collect())
        .unwrap_or_else(|| vec!["kla".into(), "mamba".into(), "gla".into(),
                                "gpt".into()]);
    let depths: Vec<usize> = args
        .get(3)
        .map(|s| s.split(',').map(|x| x.parse().unwrap()).collect())
        .unwrap_or_else(|| vec![1, 2]);

    let rt = Runtime::discover()?;
    let task = task_by_name("a5").unwrap();
    println!("A5 word problem (running products in the alternating group)");
    println!("solved = accuracy >= 0.9 (paper protocol, G.1)\n");
    println!("{:8} {}", "model",
             depths.iter().map(|d| format!("  depth {d:>2}"))
                 .collect::<String>());
    for model in &models {
        let mut row = format!("{model:8}");
        for &depth in &depths {
            let base = format!("a5_{model}_l{depth}");
            if rt.meta(&format!("{base}_train")).is_err() {
                row.push_str("      (n/a)");
                continue;
            }
            let cfg = TrainConfig {
                artifact: base,
                steps,
                seed: 0,
                eval_every: 0,
                eval_batches: 6,
                log_every: steps,
                checkpoint_dir: None,
                target_accuracy: None,
            };
            let out = kla::train::run(&rt, &cfg, task.as_ref())?;
            let solved = if out.accuracy() >= 0.9 { "*" } else { " " };
            row.push_str(&format!("  {:>7.3}{solved}", out.accuracy()));
        }
        println!("{row}");
    }
    println!("\n(* = solved; deeper baselines need `make artifacts-full`)");
    Ok(())
}

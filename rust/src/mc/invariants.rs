//! Model-checked concurrency invariants (DESIGN.md §S19).
//!
//! Each test explores a small concurrent program — built from the REAL
//! `util::thread_pool` / `serve::server::ConnSink` code, not a model of
//! it — under both exploration policies and prints one greppable result
//! line per (invariant, policy) pair:
//!
//! ```text
//! model-check[<invariant>]: dfs ok (...)
//! model-check[<invariant>]: pct ok (...)
//! ```
//!
//! CI greps these lines (and the `regression-*` detection lines) from
//! the `--features mc-shim` test run; a missing line fails the build.
//! The two `regression_*` tests seed the bug classes the wall exists
//! for (lost wakeup from a non-rechecking wait, shutdown signalled with
//! `notify_one`) and prove the checker DETECTS them — so a future
//! weakening of the real wait loops cannot pass silently.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Once};

use crate::mc::sched::{self, Config};
use crate::mc::sync::{channel, AtomicBool, AtomicUsize, Condvar, Mutex};
use crate::mc::thread::spawn_named;
use crate::serve::server::{ActiveMap, ConnSink};
use crate::serve::{EngineEvent, EngineResponse};
use crate::util::thread_pool::ThreadPool;

/// Base seed for the PCT runs; per-schedule seeds derive from it.
const PCT_SEED: u64 = 0x6b1a_c0de;

/// Explore `f` under the default DFS wall and the default PCT wall,
/// printing the result line CI greps for each.
fn check_both(inv: &str, f: impl Fn() + Send + Sync + Clone + 'static) {
    let out = sched::model(inv, Config::dfs(), f.clone());
    println!(
        "model-check[{inv}]: dfs ok ({} schedules, preemption bound 2{})",
        out.schedules,
        if out.exhausted { ", space exhausted" } else { "" }
    );
    let out = sched::model(inv, Config::pct(PCT_SEED), f);
    println!(
        "model-check[{inv}]: pct ok ({} seeded schedules, base seed \
         {PCT_SEED:#x})",
        out.schedules
    );
}

/// Shim types constructed outside any model must be plain std.
#[test]
fn shims_degrade_to_std_outside_models() {
    let m = Mutex::new(1);
    *m.lock().unwrap() += 1;
    assert_eq!(*m.lock().unwrap(), 2);
    let (tx, rx) = channel::<u32>();
    tx.send(5).unwrap();
    drop(tx);
    assert_eq!(rx.iter().collect::<Vec<_>>(), vec![5]);
    let b = AtomicBool::new(false);
    b.store(true, Ordering::SeqCst);
    assert!(b.load(Ordering::SeqCst));
    let n = AtomicUsize::new(3);
    assert_eq!(n.fetch_add(2, Ordering::SeqCst), 3);
    assert_eq!(n.load(Ordering::SeqCst), 5);
}

/// Pool lifecycle (spawn, submit, steal, scope drain, shutdown
/// broadcast, join) never deadlocks under any explored interleaving.
#[test]
fn invariant_no_deadlock() {
    check_both("no-deadlock", || {
        let pool = ThreadPool::new(2);
        pool.scope(|s| {
            for _ in 0..2 {
                s.spawn(|| {});
            }
        });
        drop(pool);
    });
}

/// The Gate's submit/sleep handshake: a submission whose `notify_one`
/// fires while the (sole) worker is between its queue sweep and its
/// `wait` must still be picked up — the generation recheck under the
/// gate lock is what makes the wakeup un-losable.
#[test]
fn invariant_no_lost_wakeup() {
    check_both("no-lost-wakeup", || {
        let pool = ThreadPool::new(1);
        let hits = AtomicUsize::new(0);
        pool.scope(|s| {
            let hits = &hits;
            s.spawn(move || {
                hits.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    });
}

/// `scope()` never returns before every spawned job has completed,
/// including jobs the caller executes itself on the work-assist path
/// (a 1-thread pool forces assists).
#[test]
fn invariant_scope_completion() {
    check_both("scope-completion", || {
        let pool = ThreadPool::new(1);
        let done = AtomicUsize::new(0);
        pool.scope(|s| {
            let done = &done;
            for _ in 0..2 {
                s.spawn(move || {
                    done.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        // the structured-concurrency contract, checked at the first
        // instant after scope() returns, under EVERY interleaving
        assert_eq!(done.load(Ordering::SeqCst), 2);
    });
}

/// A panicking job propagates out of `scope()` without losing the
/// surviving jobs, under every interleaving of bomb vs. survivor.
#[test]
fn invariant_panic_propagation() {
    quiet_bomb_panics();
    check_both("panic-propagation", || {
        let pool = ThreadPool::new(2);
        let ran = AtomicUsize::new(0);
        let out = catch_unwind(AssertUnwindSafe(|| {
            pool.scope(|s| {
                let ran = &ran;
                s.spawn(|| {
                    panic!("mc bomb");
                });
                s.spawn(move || {
                    ran.fetch_add(1, Ordering::SeqCst);
                });
            });
        }));
        assert!(out.is_err(), "scope must propagate the job panic");
        assert_eq!(
            ran.load(Ordering::SeqCst),
            1,
            "the surviving job must still run"
        );
    });
}

/// The seeded bombs above unwind once per explored schedule; silence
/// exactly their payloads so the model-check log stays readable.  The
/// hook forwards everything else (including real failures) untouched
/// and is installed once, process-wide — never racily restored.
fn quiet_bomb_panics() {
    static QUIET: Once = Once::new();
    QUIET.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let bomb = info
                .payload()
                .downcast_ref::<&str>()
                .is_some_and(|s| s.contains("mc bomb"));
            if !bomb {
                prev(info);
            }
        }));
    });
}

/// The serving sink's terminal contract: under every interleaving of
/// the engine finishing a request vs. the client disconnecting (reader
/// EOF: `closed` flips, in-flight entries sweep), the request's event
/// stream carries EXACTLY one terminal line — a `done`, or the
/// drop-time `unavailable` error — never zero, never two.
#[test]
fn invariant_terminal_exactly_once() {
    check_both("terminal-exactly-once", || {
        let (wtx, wrx) = channel::<String>();
        let closed = Arc::new(AtomicBool::new(false));
        let active: ActiveMap = Arc::new(Mutex::new(HashMap::new()));
        let cancel = Arc::new(AtomicBool::new(false));
        active.lock().unwrap().insert(7, cancel);
        let sink =
            ConnSink::for_test(7, wtx.clone(), closed.clone(), active.clone());
        // engine side: stream one event, then the terminal done, then
        // drop the sink (as finish_request does)
        let eng = spawn_named("engine", move || {
            let _ = sink.send(EngineEvent::Started { queue_ms: 0.0 });
            let _ = sink.send(EngineEvent::Done(EngineResponse {
                tokens: vec![1],
                queue_ms: 0.0,
                total_ms: 1.0,
                uncertainty: 0.5,
                cancelled: false,
                cached_tokens: 0,
            }));
            drop(sink);
        })
        .expect("spawn engine side");
        // client side: disconnect sweep from handle_conn's epilogue
        let rdr = spawn_named("reader", move || {
            closed.store(true, Ordering::SeqCst);
            if let Ok(mut map) = active.lock() {
                for (_, flag) in map.drain() {
                    flag.store(true, Ordering::SeqCst);
                }
            }
        })
        .expect("spawn reader side");
        eng.join().unwrap();
        rdr.join().unwrap();
        drop(wtx);
        let mut terminals = 0;
        for line in wrx {
            let ev = crate::util::json::parse(&line)
                .expect("sink lines are valid json");
            let kind = ev
                .req("event")
                .and_then(|e| e.as_str())
                .expect("sink lines carry an event tag");
            if kind == "done" || kind == "err" {
                terminals += 1;
            }
        }
        assert_eq!(terminals, 1, "exactly one terminal event per request");
    });
}

/// The bug class the Gate's generation recheck prevents: checking the
/// ready flag BEFORE taking the lock (no recheck under it) loses the
/// notification that lands in between.  The checker must find the
/// deadlock — proving a weakened wait loop cannot slip through.
#[test]
fn regression_lost_wakeup_detected() {
    let fail = sched::model_expect_failure(
        "buggy-gate-lost-wakeup",
        Config::dfs(),
        || {
            let ready = Arc::new(AtomicBool::new(false));
            let m = Arc::new(Mutex::new(()));
            let cv = Arc::new(Condvar::new());
            let (r2, m2, c2) = (ready.clone(), m.clone(), cv.clone());
            let h = spawn_named("waiter", move || {
                // seeded bug: flag checked outside the lock, wait not
                // re-guarded — the notify can land in the gap
                if !r2.load(Ordering::SeqCst) {
                    let g = m2.lock().unwrap();
                    let _g = c2.wait(g).unwrap();
                }
            })
            .expect("spawn waiter");
            ready.store(true, Ordering::SeqCst);
            cv.notify_one();
            h.join().unwrap();
        },
    );
    let fail = fail.expect("the checker must detect the lost wakeup");
    assert!(
        fail.detail.contains("deadlock"),
        "expected a deadlock diagnosis, got: {}",
        fail.detail
    );
    println!(
        "model-check[regression-lost-wakeup]: detected (dfs schedule {})",
        fail.schedule
    );
}

/// The bug class `ThreadPool::drop` avoids by broadcasting shutdown:
/// with two sleeping workers, `notify_one` wakes only one — the other
/// sleeps forever and the join deadlocks.  The checker must find it.
#[test]
fn regression_shutdown_broadcast_detected() {
    let fail = sched::model_expect_failure(
        "buggy-shutdown-notify-one",
        Config::dfs(),
        || {
            let m = Arc::new(Mutex::new(false));
            let cv = Arc::new(Condvar::new());
            let mut hs = Vec::new();
            for i in 0..2 {
                let (m2, c2) = (m.clone(), cv.clone());
                hs.push(
                    spawn_named(&format!("w{i}"), move || {
                        let mut g = m2.lock().unwrap();
                        while !*g {
                            g = c2.wait(g).unwrap();
                        }
                    })
                    .expect("spawn worker"),
                );
            }
            *m.lock().unwrap() = true;
            cv.notify_one(); // seeded bug: shutdown must notify_all
            for h in hs {
                h.join().unwrap();
            }
        },
    );
    let fail = fail.expect("the checker must detect the missed worker");
    assert!(
        fail.detail.contains("deadlock"),
        "expected a deadlock diagnosis, got: {}",
        fail.detail
    );
    println!(
        "model-check[regression-shutdown-broadcast]: detected \
         (dfs schedule {})",
        fail.schedule
    );
}

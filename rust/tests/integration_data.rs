//! Cross-cutting data-pipeline tests: batch invariants across all
//! generators, corpus -> tokenizer -> LM window pipeline, zero-shot suite
//! construction, and vocabulary bounds against artifact metas.

use kla::data::corpus::{Corpus, CorpusLm};
use kla::data::tokenizer::Tokenizer;
use kla::data::{task_by_name, TaskGen, MAD_TASKS};
use kla::eval::ZeroShotSuite;
use kla::util::Pcg64;

#[test]
fn batches_are_deterministic_per_seed() {
    for name in MAD_TASKS.iter().chain(["mqar", "a5"].iter()) {
        let task = task_by_name(name).unwrap();
        let a = task.batch(&mut Pcg64::seeded(42), 4, 64);
        let b = task.batch(&mut Pcg64::seeded(42), 4, 64);
        assert_eq!(a.tokens.data(), b.tokens.data(), "{name}");
        assert_eq!(a.targets.data(), b.targets.data(), "{name}");
        let c = task.batch(&mut Pcg64::seeded(43), 4, 64);
        assert_ne!(a.tokens.data(), c.tokens.data(), "{name} ignores seed");
    }
}

#[test]
fn supervised_targets_within_vocab_64() {
    // all MAD/MQAR/A5 artifacts share vocab 64
    for name in MAD_TASKS.iter().chain(["mqar", "a5"].iter()) {
        let task = task_by_name(name).unwrap();
        let mut rng = Pcg64::seeded(0);
        for _ in 0..5 {
            let b = task.batch(&mut rng, 8, 128);
            for (i, &m) in b.mask.data().iter().enumerate() {
                let tok = b.tokens.data()[i];
                assert!((0..64).contains(&tok), "{name}: token {tok}");
                if m > 0.0 {
                    let tgt = b.targets.data()[i];
                    assert!((0..64).contains(&tgt), "{name}: target {tgt}");
                }
            }
        }
    }
}

#[test]
fn mask_density_reasonable() {
    // every task must supervise something but not everything (except
    // a5/corpus which supervise all positions)
    for name in MAD_TASKS {
        let task = task_by_name(name).unwrap();
        let mut rng = Pcg64::seeded(1);
        let b = task.batch(&mut rng, 8, 128);
        let density = b.mask_density();
        assert!(density > 0.02, "{name}: mask too sparse ({density})");
        assert!(density < 0.95, "{name}: mask suspiciously dense");
    }
    let a5 = task_by_name("a5").unwrap();
    let b = a5.batch(&mut Pcg64::seeded(1), 4, 24);
    assert_eq!(b.mask_density(), 1.0);
}

#[test]
fn corpus_to_lm_pipeline() {
    let (lm, tok, corpus) = CorpusLm::build(3, 60_000, 512).unwrap();
    assert!(tok.vocab_size() <= 512);
    assert!(lm.tokens() > 5_000);
    // windows decode back to corpus-like text
    let mut rng = Pcg64::seeded(0);
    let s = lm.sample(&mut rng, 64);
    let ids: Vec<u32> = s.tokens.iter().map(|&x| x as u32).collect();
    let text = tok.decode(&ids);
    assert!(text.len() > 32);
    // train facts should be taught somewhere in the stream
    let full = corpus.generate(60_000);
    let taught = corpus
        .train_facts
        .iter()
        .filter(|f| full.contains(&f.sentence()))
        .count();
    assert!(taught > corpus.train_facts.len() / 2);
}

#[test]
fn tokenizer_handles_corpus_vocabulary() {
    let corpus = Corpus::new(5);
    let text = corpus.generate(50_000);
    let tok = Tokenizer::train(&text, 512).unwrap();
    // every fact sentence (train AND held-out) round-trips
    for f in corpus.train_facts.iter().chain(&corpus.heldout_facts) {
        let s = f.sentence();
        assert_eq!(tok.decode(&tok.encode(&s)), s);
    }
}

#[test]
fn zeroshot_suite_answers_well_formed() {
    let corpus = Corpus::new(7);
    let suite = ZeroShotSuite::build(&corpus, 7, 6);
    assert!(suite.items.len() >= 30, "only {} items", suite.items.len());
    // answer positions roughly uniform (shuffling works)
    let mut pos_counts = [0usize; 4];
    for item in &suite.items {
        pos_counts[item.answer] += 1;
    }
    assert!(pos_counts[0] < suite.items.len(),
            "answers never shuffled: {pos_counts:?}");
    // contexts reference corpus entities
    let hit = suite
        .items
        .iter()
        .filter(|i| i.context.contains("the capital of")
            || i.context.contains("exports")
            || i.context.contains("river"))
        .count();
    assert!(hit > suite.items.len() / 3);
}

#[test]
fn batch_shapes_match_artifact_metas() {
    // if artifacts exist, the generator vocab assumptions must match them
    let Ok(rt) = kla::runtime::Runtime::discover() else { return };
    for (name, expect_vocab) in [("mad_kla_train", 64),
                                 ("mqar_kla_d64_train", 64),
                                 ("a5_kla_l1_train", 64),
                                 ("lm_kla_train", 512)] {
        let meta = rt.meta(name).unwrap();
        assert_eq!(meta.model.vocab, expect_vocab, "{name}");
    }
}

fn main() { println!("todo"); }

//! Timing helpers shared by the trainer, server and bench harness.

use std::time::{Duration, Instant};

/// Simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Streaming mean/min/max/percentile accumulator for latency tracking.
#[derive(Clone, Debug, Default)]
pub struct Stats {
    samples: Vec<f64>,
}

impl Stats {
    pub fn new() -> Self {
        Stats { samples: Vec::new() }
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        let m = self.mean();
        if self.samples.len() < 2 {
            return 0.0;
        }
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.samples.len() - 1) as f64)
            .sqrt()
    }

    /// Linear-interpolated percentile, p in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut xs = self.samples.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = p / 100.0 * (xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            xs[lo]
        } else {
            xs[lo] + (xs[hi] - xs[lo]) * (rank - lo as f64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let mut s = Stats::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.percentile(50.0) - 3.0).abs() < 1e-12);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-12);
        assert!((s.percentile(100.0) - 5.0).abs() < 1e-12);
        assert!((s.std() - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_stats_nan() {
        let s = Stats::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
    }
}

//! `repro` — launcher CLI for the KLA reproduction.
//!
//! Commands mirror the experiment index in DESIGN.md §2; the heavier
//! sweeps live in `rust/benches/` (run via `cargo bench`).

use anyhow::{anyhow, bail, Result};

use kla::cli::{App, Command, Matches};
use kla::config::{ConfigMap, ServeConfig, TrainConfig};
use kla::data::{task_by_name, MAD_TASKS};
use kla::runtime::Runtime;
use kla::util::logging;

fn app() -> App {
    App::new("repro", "Kalman Linear Attention reproduction (rust+jax+pallas)")
        .command(
            Command::new("train", "train one artifact on one task")
                .req("artifact", "artifact base, e.g. mad_kla")
                .req("task", "task name, e.g. selective_copy")
                .opt("steps", "200", "optimisation steps")
                .opt("seed", "0", "data seed")
                .opt("eval-every", "50", "eval period (0 = off)")
                .opt("eval-batches", "4", "batches per eval")
                .opt("checkpoint-dir", "", "save params here if non-empty")
                .opt("config", "", "optional TOML-lite config file"),
        )
        .command(
            Command::new("mad", "run the MAD suite for one mixer")
                .opt("model", "kla", "kla|kla_plus|mamba|gla|gdn|kla_nonoise|kla_noou")
                .opt("steps", "200", "steps per task")
                .opt("seed", "0", "seed"),
        )
        .command(
            Command::new("serve", "serve a KLA model (O(1) belief-state decode)")
                .opt("backend", "xla", "decode backend: xla|native")
                .opt("artifact", "serve_kla_b8", "decode artifact base (xla)")
                .opt("addr", "127.0.0.1:7878", "listen address")
                .opt("checkpoint", "", "load params from checkpoint")
                .opt("max-new", "32", "default max new tokens")
                .opt("window-us", "500", "batching window (microseconds)")
                .opt("batch", "8", "batch slots (native backend)")
                .opt("prefill-chunk", "64",
                     "prompt tokens per scan-prefill call, native \
                      backend (1 = token-by-token prefill; xla always \
                      interleaves token-by-token)")
                .opt("prefill-threads", "0",
                     "worker threads for the fused (slots x time) \
                      prefill round, native backend (0 = auto from \
                      batch width and core count)")
                .opt("pad", "0", "pad token id for idle lanes and empty \
                      prompts")
                .opt("temperature", "0",
                     "default sampling temperature (0 = greedy argmax)")
                .opt("top-k", "0", "default top-k cutoff (0 = off, 1 = \
                      greedy)")
                .opt("top-p", "1", "default nucleus mass (>= 1 = off)")
                .opt("uncertainty-temp", "0",
                     "scale temperature by belief uncertainty: \
                      tau*(1 + c*u)")
                .opt("stop", "", "default stop token ids, comma-separated")
                .opt("max-new-limit", "1024",
                     "reject requests asking for more than this many \
                      new tokens")
                .opt("max-inflight", "64",
                     "max multiplexed in-flight requests per connection \
                      (protocol v2 streaming sessions)")
                .opt("prefix-cache-mb", "0",
                     "belief-state prefix cache budget in MiB (0 = off; \
                      chunked-prefill native backend only)")
                .opt("prefix-cache-block", "0",
                     "prefix-cache snapshot granularity in prompt \
                      tokens (0 = use prefill-chunk)")
                .opt("seed", "0", "engine seed: keys the sampling RNG, \
                      and the weight init (native, no checkpoint)")
                .opt("vocab", "64", "vocab size (native, no checkpoint)")
                .opt("d-model", "32", "model width (native, no checkpoint)")
                .opt("layers", "2", "layer count (native, no checkpoint)")
                .opt("n-state", "4", "state expansion N (native, no checkpoint)")
                .flag("no-process-noise",
                      "native: weights trained with pbar=0 (Fig. 6b ablation)")
                .flag("no-ou-exact",
                      "native: weights trained with Euler OU (Fig. 3b ablation)"),
        )
        .command(
            Command::new("scaling", "native recurrent-vs-scan scaling (Fig. 4 core)")
                .opt("lengths", "256,1024,4096,16384", "sequence lengths")
                .opt("n", "8", "state expansion N")
                .opt("d", "64", "channels D")
                .opt("threads", "0", "0 = all cores"),
        )
        .command(
            Command::new("inspect", "list artifacts and their shapes")
                .opt("filter", "", "name prefix filter"),
        )
        .command(
            Command::new("gen", "print samples from a task generator")
                .req("task", "task name")
                .opt("t", "64", "sequence length")
                .opt("count", "2", "how many samples")
                .opt("seed", "0", "seed"),
        )
        .command(
            Command::new("attnmap", "ASCII Kalman attention map (Figs. 10-13)")
                .opt("t", "48", "sequence length")
                .opt("seed", "0", "seed"),
        )
}

fn main() {
    logging::level_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let app = app();
    let code = match app.parse(&argv) {
        Ok(m) => match dispatch(&m) {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("error: {e:#}");
                1
            }
        },
        Err(e) => {
            eprintln!("{e}");
            2
        }
    };
    std::process::exit(code);
}

fn dispatch(m: &Matches) -> Result<()> {
    match m.command.as_str() {
        "train" => cmd_train(m),
        "mad" => cmd_mad(m),
        "serve" => cmd_serve(m),
        "scaling" => cmd_scaling(m),
        "inspect" => cmd_inspect(m),
        "gen" => cmd_gen(m),
        "attnmap" => cmd_attnmap(m),
        other => bail!("unhandled command {other}"),
    }
}

fn cmd_train(m: &Matches) -> Result<()> {
    let rt = Runtime::discover()?;
    let mut cfg = if m.get("config")?.is_empty() {
        TrainConfig::default()
    } else {
        TrainConfig::from_map(&ConfigMap::load(m.get("config")?)?)?
    };
    cfg.artifact = m.get_string("artifact")?;
    cfg.steps = m.get_usize("steps")?;
    cfg.seed = m.get_u64("seed")?;
    cfg.eval_every = m.get_usize("eval-every")?;
    cfg.eval_batches = m.get_usize("eval-batches")?;
    let ckpt = m.get_string("checkpoint-dir")?;
    if !ckpt.is_empty() {
        cfg.checkpoint_dir = Some(ckpt);
    }
    let task_name = m.get_string("task")?;
    let task = task_by_name(&task_name)
        .ok_or_else(|| anyhow!("unknown task {task_name}"))?;
    let outcome = kla::train::run(&rt, &cfg, task.as_ref())?;
    println!(
        "{} on {}: final loss {:.4}, accuracy {:.4} ({} steps, {:.1} ms/step)",
        outcome.base, outcome.task, outcome.final_loss,
        outcome.accuracy(), outcome.steps, outcome.mean_step_ms()
    );
    Ok(())
}

fn cmd_mad(m: &Matches) -> Result<()> {
    let rt = Runtime::discover()?;
    let model = m.get_string("model")?;
    let steps = m.get_usize("steps")?;
    let seed = m.get_u64("seed")?;
    println!("MAD suite — model {model}, {steps} steps/task");
    for task_name in MAD_TASKS {
        let task = task_by_name(task_name).unwrap();
        let cfg = TrainConfig {
            artifact: format!("mad_{model}"),
            steps,
            seed,
            eval_every: 0,
            eval_batches: 8,
            log_every: steps.max(1),
            checkpoint_dir: None,
            target_accuracy: None,
        };
        let outcome = kla::train::run(&rt, &cfg, task.as_ref())?;
        println!("  {task_name:16} acc {:.4}  loss {:.4}",
                 outcome.accuracy(), outcome.final_loss);
    }
    Ok(())
}

fn cmd_serve(m: &Matches) -> Result<()> {
    let stop_tokens: Vec<i32> = m
        .get_list("stop")?
        .iter()
        .map(|s| s.parse::<i32>()
            .map_err(|e| anyhow!("--stop: {s:?} is not a token id: {e}")))
        .collect::<Result<_>>()?;
    let cfg = ServeConfig {
        addr: m.get_string("addr")?,
        backend: m.get_string("backend")?,
        artifact: m.get_string("artifact")?,
        max_new_tokens: m.get_usize("max-new")?,
        max_new_limit: m.get_usize("max-new-limit")?,
        max_inflight: m.get_usize("max-inflight")?,
        batch_window_us: m.get_u64("window-us")?,
        seed: m.get_u64("seed")?,
        temperature: m.get_f64("temperature")?,
        top_k: m.get_usize("top-k")?,
        top_p: m.get_f64("top-p")?,
        uncertainty_temp: m.get_f64("uncertainty-temp")?,
        stop_tokens,
        prefill_chunk: m.get_usize("prefill-chunk")?,
        prefill_threads: m.get_usize("prefill-threads")?,
        prefix_cache_bytes: m.get_usize("prefix-cache-mb")? * (1 << 20),
        prefix_cache_block: m.get_usize("prefix-cache-block")?,
        pad: m.get("pad")?
            .parse::<i32>()
            .map_err(|e| anyhow!("--pad: not an i32: {e}"))?,
        ..Default::default()
    };
    let ckpt = m.get_string("checkpoint")?;
    let handle = match cfg.backend.as_str() {
        // pure-Rust path: no artifacts, no PJRT — weights from the
        // checkpoint if given, else a deterministic seeded init
        "native" => {
            use kla::runtime::NativeBackend;
            let batch = m.get_usize("batch")?;
            // the flatten ABI does not record the two ablation switches,
            // so they must match how the checkpoint was trained
            let process_noise = !m.get_flag("no-process-noise");
            let ou_exact = !m.get_flag("no-ou-exact");
            let backend = if ckpt.is_empty() {
                let lm_cfg = kla::kla::NativeLmConfig {
                    vocab: m.get_usize("vocab")?,
                    d_model: m.get_usize("d-model")?,
                    n_layers: m.get_usize("layers")?,
                    n_state: m.get_usize("n-state")?,
                    process_noise,
                    ou_exact,
                    ..Default::default()
                };
                NativeBackend::seeded(&lm_cfg, cfg.seed, batch)
            } else {
                NativeBackend::from_checkpoint(
                    std::path::Path::new(&ckpt), batch, process_noise,
                    ou_exact)?
            };
            // fused-prefill plan: 0 = auto (resolved per round from
            // batch width, prompt lengths, and the core count)
            let plan = match cfg.prefill_threads {
                0 => kla::api::ScanPlan::auto(),
                n => kla::api::ScanPlan::chained(n),
            };
            let backend = backend.with_prefill_plan(plan);
            kla::serve::serve_native(backend, &cfg)?
        }
        "xla" => {
            let rt = Runtime::discover()?;
            // params: checkpoint if given, else fresh init from the
            // lm artifact
            let params = if ckpt.is_empty() {
                let init = rt.load("lm_kla_init")?;
                init.run(&[])?
            } else {
                kla::train::checkpoint::load(std::path::Path::new(&ckpt))?
            };
            kla::serve::serve(rt.dir().to_path_buf(),
                              cfg.artifact.clone(), params, &cfg)?
        }
        other => bail!("unknown backend {other:?} (use xla|native)"),
    };
    println!("serving on {} ({} backend) — Ctrl-C to stop", handle.addr,
             cfg.backend);
    // block forever (the handle's engine thread does the work)
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_scaling(m: &Matches) -> Result<()> {
    use kla::api::{Filter, KlaFilter, ScanPlan};
    use kla::kla::{random_inputs, random_params};
    use kla::util::{Pcg64, Timer};
    let lengths: Vec<usize> = m
        .get_list("lengths")?
        .iter()
        .map(|s| s.parse().unwrap())
        .collect();
    let n = m.get_usize("n")?;
    let d = m.get_usize("d")?;
    let mut threads = m.get_usize("threads")?;
    if threads == 0 {
        threads = kla::util::pool::default_threads();
    }
    println!("{:>8} {:>14} {:>14} {:>10}", "T", "recurrent ms",
             "chunked ms", "speedup");
    for &t in &lengths {
        let mut rng = Pcg64::seeded(t as u64);
        let p = random_params(&mut rng, n, d);
        let inp = random_inputs(&mut rng, t, n, d);
        let prior = KlaFilter::init(&p);
        let timer = Timer::start();
        let (seq, _) =
            KlaFilter::prefix(&p, &inp, &prior, &ScanPlan::sequential());
        let seq_ms = timer.elapsed_ms();
        let timer = Timer::start();
        let (par, _) = KlaFilter::prefix(&p, &inp, &prior,
                                         &ScanPlan::chunked(threads));
        let par_ms = timer.elapsed_ms();
        assert!(seq.y.iter().zip(&par.y).all(|(a, b)| (a - b).abs() < 1e-2));
        println!("{t:>8} {seq_ms:>14.2} {par_ms:>14.2} {:>9.2}x",
                 seq_ms / par_ms);
    }
    Ok(())
}

fn cmd_inspect(m: &Matches) -> Result<()> {
    let rt = Runtime::discover()?;
    let filter = m.get_string("filter")?;
    for name in rt.names()? {
        if !filter.is_empty() && !name.starts_with(&filter) {
            continue;
        }
        let meta = rt.meta(&name)?;
        println!(
            "{name:40} {:8} {:12} B={:<3} T={:<5} params={} ({} elems)",
            meta.role, meta.model.kind, meta.batch, meta.seq,
            meta.n_params(), meta.total_param_elems()
        );
    }
    Ok(())
}

fn cmd_gen(m: &Matches) -> Result<()> {
    let task_name = m.get_string("task")?;
    let task = task_by_name(&task_name)
        .ok_or_else(|| anyhow!("unknown task {task_name}"))?;
    let t = m.get_usize("t")?;
    let mut rng = kla::util::Pcg64::seeded(m.get_u64("seed")?);
    for i in 0..m.get_usize("count")? {
        let s = task.sample(&mut rng, t);
        println!("-- sample {i}");
        println!("tokens : {:?}", s.tokens);
        println!("targets: {:?}", s.targets);
        println!("mask   : {:?}",
                 s.mask.iter().map(|&x| x as u8).collect::<Vec<_>>());
    }
    Ok(())
}

fn cmd_attnmap(m: &Matches) -> Result<()> {
    use kla::eval::attnmap::{kalman_attention, render_ascii};
    use kla::kla::{random_inputs, random_params};
    let t = m.get_usize("t")?;
    let mut rng = kla::util::Pcg64::seeded(m.get_u64("seed")?);
    let p = random_params(&mut rng, 2, 2);
    let inp = random_inputs(&mut rng, t, 2, 2);
    for (ni, di) in [(0, 0), (1, 1)] {
        println!("channel (n={ni}, d={di}):");
        let w = kalman_attention(&p, &inp, ni, di);
        println!("{}", render_ascii(&w, t, 48.min(t)));
    }
    Ok(())
}

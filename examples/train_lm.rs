//! END-TO-END driver (DESIGN.md "End-to-end validation"): pretrain a small
//! KLA language model on the synthetic corpus, log the loss curve, run the
//! zero-shot suite, and save a checkpoint servable by `repro serve`.
//!
//!   cargo run --release --example train_lm [steps] [model]
//!
//! Defaults: 300 steps, model "kla" (artifacts lm_kla_*).  Set model to
//! gpt / hybrid_kla (default manifest) or mamba / gdn (make artifacts-full).

use anyhow::Result;
use kla::config::TrainConfig;
use kla::data::corpus::CorpusLm;
use kla::eval::ZeroShotSuite;
use kla::runtime::{Runtime, ScoreSession, TrainSession};
use kla::train::checkpoint;
use kla::util::{Pcg64, Timer};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let model = args.get(2).cloned().unwrap_or_else(|| "kla".into());
    let base = format!("lm_{model}");
    let seed = 0u64;

    let rt = Runtime::discover()?;
    let meta = rt.meta(&format!("{base}_train"))?;
    println!("== end-to-end LM pretraining ==");
    println!("model {} | d_model {} | layers {} | vocab {} | B {} | T {}",
             meta.model.kind, meta.model.d_model, meta.model.n_layers,
             meta.model.vocab, meta.batch, meta.seq);

    // data: corpus -> BPE(512) -> token stream
    let timer = Timer::start();
    let (lm_data, tok, corpus) =
        CorpusLm::build(seed, 2_000_000, meta.model.vocab)?;
    println!("corpus: {} tokens via BPE-{} ({:.1} ms to build)",
             lm_data.tokens(), tok.vocab_size(), timer.elapsed_ms());

    // train
    let cfg = TrainConfig {
        artifact: base.clone(),
        steps,
        seed,
        eval_every: (steps / 4).max(1),
        eval_batches: 2,
        log_every: (steps / 20).max(1),
        checkpoint_dir: Some("checkpoints".into()),
        target_accuracy: None,
    };
    let outcome = kla::train::run(&rt, &cfg, &lm_data)?;
    println!("\nloss curve (step, loss):");
    for (s, l) in &outcome.losses {
        println!("  {s:>6} {l:.4}");
    }
    let tokens_seen = outcome.steps * meta.batch * meta.seq;
    println!("trained {} steps = {:.2}M tokens at {:.0} ms/step \
              ({:.0} tok/s)",
             outcome.steps, tokens_seen as f64 / 1e6,
             outcome.mean_step_ms(),
             (meta.batch * meta.seq) as f64 / outcome.mean_step_ms() * 1e3);
    println!("final eval: loss {:.4}, next-token acc {:.4}",
             outcome.eval.mean_loss(), outcome.accuracy());

    // zero-shot suite (Table 4 protocol)
    println!("\n== zero-shot suite (8 synthetic families) ==");
    let session = TrainSession::new(&rt, &base)?; // for shapes only
    let _ = session;
    let ckpt = checkpoint::path_for("checkpoints", &base);
    let params = checkpoint::load(&ckpt)?;
    let scorer = ScoreSession::new(&rt, &base, params)?;
    let suite = ZeroShotSuite::build(&corpus, seed, 8);
    let report = suite.evaluate(&scorer, &tok)?;
    for (task, acc, n) in &report.per_task {
        println!("  {task:12} acc {acc:.3}  (n={n})");
    }
    println!("  {:12} acc {:.3}", "AVERAGE", report.average());
    println!("\ncheckpoint: {}", ckpt.display());
    println!("serve it:   repro serve --artifact serve_{model}_b8 \
              --checkpoint {}", ckpt.display());
    Ok(())
}

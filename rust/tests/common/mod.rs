//! Helpers shared by the serve-side integration suites
//! (`integration_serve`, `integration_stream`).  Each [[test]] target
//! compiles its own copy, so items unused by one target are expected —
//! hence the allow.
#![allow(dead_code)]

use kla::config::ServeConfig;
use kla::kla::NativeLmConfig;
use kla::util::Json;

/// The `tokens` array of a one-shot reply (or `done` event shape).
pub fn tokens_of(r: &Json) -> Vec<i64> {
    r.req("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_i64().unwrap())
        .collect()
}

/// The shared tiny native LM every serve-side e2e test runs on — keep
/// the two suites on the SAME model geometry (vocab 32, conv window
/// K-1 = 3) so their pinned token sequences stay comparable.
pub fn small_lm() -> NativeLmConfig {
    NativeLmConfig {
        vocab: 32,
        d_model: 16,
        n_layers: 2,
        n_state: 2,
        conv_kernel: 4,
        ..Default::default()
    }
}

/// Server config for the native-backend e2e tests: ephemeral port, and
/// a wide batch window — native steps are microseconds (vs ms on PJRT),
/// so concurrent submitters need the window to land in the same batch.
pub fn native_cfg() -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        backend: "native".into(),
        batch_window_us: 2000,
        max_new_tokens: 4,
        ..Default::default()
    }
}

//! `DecodeBackend` — the execution seam for O(1) autoregressive decoding.
//!
//! The serve stack (engine, continuous batcher, `BeliefStateCache`, TCP
//! server) needs exactly three things from a model: a fixed batch width,
//! a fresh belief state, and a one-token step `(tokens, state) ->
//! (logits, state')`.  This trait is that contract; the engine, the
//! state cache, and the server are generic over it.
//!
//! Two implementations:
//!
//! - [`crate::runtime::DecodeSession`] — the XLA/PJRT path over a
//!   `{base}_decode` HLO artifact (requires `make artifacts`);
//! - [`NativeBackend`] — a pure-Rust KLA LM (`kla::model::NativeLm`)
//!   whose per-layer filter update goes through the same
//!   `kla::api::Filter::step()` carry the training-side scan uses.  No
//!   artifacts needed: weights come from a deterministic seeded init or
//!   a `train::checkpoint` file, so the whole continuous-batching stack
//!   runs (and is tested) offline.
//!
//! Both backends share the `DecodeState` layout (L,B,K-1,D) /
//! (L,B,N,D), so slot pooling, snapshot/restore, and the uncertainty
//! signal work unchanged on either path.

use std::path::Path;

use anyhow::{bail, Result};

use crate::api::ScanPlan;
use crate::kla::model::{NativeLm, NativeLmConfig};
use crate::tensor::{IntTensor, Tensor};

/// One model's recurrent decode state: (conv, lam, eta), shapes
/// (L,B,K-1,D) / (L,B,N,D) / (L,B,N,D).  Slots live in the batch
/// dimension (see `crate::serve::state_cache`).
#[derive(Clone, Debug)]
pub struct DecodeState {
    pub conv: Tensor,
    pub lam: Tensor,
    pub eta: Tensor,
}

impl DecodeState {
    /// Batch width B of this state.
    pub fn batch(&self) -> usize {
        self.lam.shape()[1]
    }

    /// Extract one batch lane as a standalone B=1 state — the shape
    /// `DecodeBackend::prefill` returns and
    /// `crate::serve::BeliefStateCache::write_slot` accepts.
    pub fn slot(&self, slot: usize) -> Result<DecodeState> {
        Ok(DecodeState {
            conv: take_lane(&self.conv, slot)?,
            lam: take_lane(&self.lam, slot)?,
            eta: take_lane(&self.eta, slot)?,
        })
    }
}

/// Copy lane `slot` of a (L,B,R,C) tensor into a fresh (L,1,R,C) one.
fn take_lane(t: &Tensor, slot: usize) -> Result<Tensor> {
    let s = t.shape();
    if s.len() != 4 {
        bail!("decode state tensors are 4-D, got {s:?}");
    }
    let (l, b, row) = (s[0], s[1], s[2] * s[3]);
    if slot >= b {
        bail!("slot {slot} out of range for batch {b}");
    }
    let mut out = Tensor::zeros(&[l, 1, s[2], s[3]]);
    for li in 0..l {
        let src = (li * b + slot) * row;
        out.data_mut()[li * row..(li + 1) * row]
            .copy_from_slice(&t.data()[src..src + row]);
    }
    Ok(out)
}

/// A decode execution backend: init state + step a batch of tokens.
pub trait DecodeBackend {
    /// Fixed batch width (the serving engine's slot count).
    fn batch(&self) -> usize;

    /// Vocabulary size.  The serving engine clamps incoming token ids
    /// into [0, vocab) before `step()` (the native model additionally
    /// clamps internally; the XLA gather does not).
    fn vocab(&self) -> usize;

    /// Short backend tag for logs: "native" | "xla".
    fn kind(&self) -> &'static str;

    /// Fresh state for `batch()` sequences at the learned prior.
    fn init_state(&self) -> Result<DecodeState>;

    /// One autoregressive step for the whole batch:
    /// tokens (B,) -> (logits (B, V), new state).
    fn step(&self, tokens: &IntTensor, state: &DecodeState)
            -> Result<(Tensor, DecodeState)>;

    /// Whether `prefill()` is genuinely time-parallel (a scan), i.e.
    /// cheaper than feeding tokens one per batched `step()`.  The
    /// serving engine only routes prompts through chunked prefill when
    /// this is true: for a backend stuck with the sequential fallback
    /// (the XLA artifact), chunked prefill would spend T dedicated
    /// batch-wide steps per prompt that the legacy interleaved path
    /// shares with concurrent decode lanes — strictly more work.
    fn prefill_is_parallel(&self) -> bool {
        false
    }

    /// Consume a whole prompt chunk for ONE batch lane: `tokens` (T,
    /// non-empty) are fed in order starting from lane `slot` of `state`;
    /// returns the logits (V,) after the last token plus the advanced
    /// single-lane (B=1) state — the engine writes it back with
    /// `crate::serve::BeliefStateCache::write_slot`.  No other lane of
    /// `state` is advanced.
    ///
    /// The default implementation is a correct sequential fallback over
    /// `step()` for backends whose execution graph is fixed at one token
    /// per call (the XLA decode artifact): it steps a scratch copy of
    /// the batched state and keeps only lane `slot`.  Backends with a
    /// native time-parallel scan override this with a chunked prefix
    /// (`NativeBackend` runs `kla::api::Filter::prefix` per layer).
    fn prefill(&self, tokens: &IntTensor, slot: usize,
               state: &DecodeState) -> Result<(Tensor, DecodeState)> {
        let ts = tokens.shape();
        if ts.len() != 1 || ts[0] == 0 {
            bail!("prefill wants non-empty (T,) tokens, got {ts:?}");
        }
        let b = self.batch();
        if slot >= b {
            bail!("prefill slot {slot} out of range for batch {b}");
        }
        let mut cur = state.clone();
        let mut last: Option<Tensor> = None;
        for &tok in tokens.data() {
            // every lane gets the same token; all but `slot` are scratch
            let (logits, next) =
                self.step(&IntTensor::new(&[b], vec![tok; b])?, &cur)?;
            cur = next;
            last = Some(logits);
        }
        let v = self.vocab();
        let logits = last.expect("tokens checked non-empty");
        let row = logits.data()[slot * v..(slot + 1) * v].to_vec();
        Ok((Tensor::new(&[v], row)?, cur.slot(slot)?))
    }

    /// Fused multi-dimensional (slots × time) prefill round: one ragged
    /// token chunk per lane, all consumed in one call.  Returns exactly
    /// one entry per input lane, in submission order, each carrying
    /// that lane's own `Result` — a failing lane never poisons its
    /// neighbours, which is what lets the serving engine fail a single
    /// request instead of the whole round (per-slot fault isolation).
    ///
    /// The default implementation loops `prefill()` per lane (the XLA
    /// path, whose execution graph is fixed per call, keeps exactly its
    /// old per-slot cost).  Backends with a native multi-lane scan
    /// override it: `NativeBackend` hands the whole ragged batch to
    /// `NativeLm::prefill_ragged`, which chains lanes across the shared
    /// work-stealing pool, so one burst of admissions costs one fused
    /// scan round instead of B serial ones.
    fn prefill_batch(&self, lanes: &[(usize, &[i32])],
                     state: &DecodeState)
                     -> Vec<(usize, Result<(Tensor, DecodeState)>)> {
        per_slot_prefill(self, lanes, state)
    }
}

/// The per-slot `prefill_batch` fallback: one `prefill()` call per lane,
/// each lane's error captured on its own entry.
fn per_slot_prefill<B: DecodeBackend + ?Sized>(
    be: &B, lanes: &[(usize, &[i32])], state: &DecodeState)
    -> Vec<(usize, Result<(Tensor, DecodeState)>)> {
    lanes
        .iter()
        .map(|&(slot, toks)| {
            let res = IntTensor::new(&[toks.len()], toks.to_vec())
                .and_then(|t| be.prefill(&t, slot, state));
            (slot, res)
        })
        .collect()
}

/// The pure-Rust backend: a `NativeLm` pinned to a fixed batch width.
pub struct NativeBackend {
    lm: NativeLm,
    batch: usize,
    /// Scan strategy for `prefill()` / `prefill_batch()` chunks.  `Auto`
    /// by default, which resolves by (lanes, T, cores): multi-lane
    /// rounds go lane-chained across the shared `util::thread_pool`
    /// (each lane sequential, bit-exact), single short chunks stay
    /// sequential, and long single chunks go time-chunked.  Override
    /// with `ScanPlan::chained(threads)` to pin the lane worker count,
    /// or `ScanPlan::blelloch()` for the O(log T) tree shape.
    prefill_plan: ScanPlan,
}

impl NativeBackend {
    pub fn new(lm: NativeLm, batch: usize) -> Self {
        assert!(batch >= 1, "backend batch must be >= 1");
        NativeBackend { lm, batch, prefill_plan: ScanPlan::auto() }
    }

    /// Override the scan plan `prefill()` uses per layer.
    pub fn with_prefill_plan(mut self, plan: ScanPlan) -> Self {
        self.prefill_plan = plan;
        self
    }

    /// Deterministic seeded weights (same seed => same tokens out).
    pub fn seeded(cfg: &NativeLmConfig, seed: u64, batch: usize) -> Self {
        Self::new(NativeLm::seeded(cfg, seed), batch)
    }

    /// Load weights from a flatten-ABI param list (init artifact output
    /// or checkpoint contents).
    pub fn from_values(values: &[crate::runtime::Value], batch: usize,
                       process_noise: bool, ou_exact: bool)
                       -> Result<Self> {
        Ok(Self::new(NativeLm::from_values(values, process_noise,
                                           ou_exact)?,
                     batch))
    }

    /// Load weights from a `train::checkpoint` file.
    pub fn from_checkpoint(path: &Path, batch: usize, process_noise: bool,
                           ou_exact: bool) -> Result<Self> {
        let values = crate::train::checkpoint::load(path)?;
        Self::from_values(&values, batch, process_noise, ou_exact)
    }

    pub fn lm(&self) -> &NativeLm {
        &self.lm
    }
}

impl DecodeBackend for NativeBackend {
    fn batch(&self) -> usize {
        self.batch
    }

    fn vocab(&self) -> usize {
        self.lm.cfg.vocab
    }

    fn kind(&self) -> &'static str {
        "native"
    }

    fn init_state(&self) -> Result<DecodeState> {
        Ok(self.lm.init_state(self.batch))
    }

    fn step(&self, tokens: &IntTensor, state: &DecodeState)
            -> Result<(Tensor, DecodeState)> {
        self.lm.step(tokens, state)
    }

    fn prefill_is_parallel(&self) -> bool {
        true
    }

    fn prefill(&self, tokens: &IntTensor, slot: usize,
               state: &DecodeState) -> Result<(Tensor, DecodeState)> {
        self.lm.prefill_slot(tokens, slot, state, &self.prefill_plan)
    }

    fn prefill_batch(&self, lanes: &[(usize, &[i32])],
                     state: &DecodeState)
                     -> Vec<(usize, Result<(Tensor, DecodeState)>)> {
        match self.lm.prefill_ragged(lanes, state, &self.prefill_plan) {
            Ok(rows) => rows
                .into_iter()
                .map(|(slot, logits, lane)| (slot, Ok((logits, lane))))
                .collect(),
            // A structural error (empty chunk, bad/duplicate slot)
            // failed the fused call before any scan ran; degrade to the
            // per-slot loop so only the offending lanes carry errors.
            Err(_) => per_slot_prefill(self, lanes, state),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> NativeBackend {
        let cfg = NativeLmConfig {
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_state: 2,
            conv_kernel: 3,
            ..Default::default()
        };
        NativeBackend::seeded(&cfg, 42, 3)
    }

    #[test]
    fn native_backend_shapes_and_kind() {
        let be = backend();
        assert_eq!(be.batch(), 3);
        assert_eq!(be.vocab(), 16);
        assert_eq!(be.kind(), "native");
        let st = be.init_state().unwrap();
        assert_eq!(st.conv.shape(), &[2, 3, 2, 8]);
        assert_eq!(st.lam.shape(), &[2, 3, 2, 8]);
        assert_eq!(st.eta.shape(), &[2, 3, 2, 8]);
    }

    #[test]
    fn native_backend_step_is_deterministic() {
        let be = backend();
        let toks = IntTensor::new(&[3], vec![1, 2, 3]).unwrap();
        let st = be.init_state().unwrap();
        let (a, sa) = be.step(&toks, &st).unwrap();
        let (b, sb) = be.step(&toks, &st).unwrap();
        assert_eq!(a.data(), b.data());
        assert_eq!(sa.lam.data(), sb.lam.data());
        assert_eq!(a.shape(), &[3, 16]);
    }

    #[test]
    fn native_backend_usable_as_trait_object() {
        let be = backend();
        let dynref: &dyn DecodeBackend = &be;
        assert_eq!(dynref.batch(), 3);
        assert!(dynref.init_state().is_ok());
    }

    /// Delegates everything but `prefill` — exercises the trait's
    /// sequential fallback (the XLA path's code shape) against the
    /// native scan override.
    struct SeqOnly(NativeBackend);

    impl DecodeBackend for SeqOnly {
        fn batch(&self) -> usize {
            self.0.batch()
        }
        fn vocab(&self) -> usize {
            self.0.vocab()
        }
        fn kind(&self) -> &'static str {
            "seq-only"
        }
        fn init_state(&self) -> Result<DecodeState> {
            self.0.init_state()
        }
        fn step(&self, tokens: &IntTensor, state: &DecodeState)
                -> Result<(Tensor, DecodeState)> {
            self.0.step(tokens, state)
        }
    }

    #[test]
    fn prefill_fallback_and_scan_override_agree() {
        let be = backend();
        let st = be.init_state().unwrap();
        let toks =
            IntTensor::new(&[9], (0..9).map(|i| i % 16).collect()).unwrap();
        let slot = 2usize;
        let (lg_seq, lane_seq) = SeqOnly(backend())
            .prefill(&toks, slot, &st)
            .unwrap();
        let (lg_scan, lane_scan) = be.prefill(&toks, slot, &st).unwrap();
        assert_eq!(lg_seq.shape(), &[16]);
        assert_eq!(lane_seq.batch(), 1);
        assert_eq!(lane_scan.batch(), 1);
        let close =
            |a: f32, e: f32| crate::testing::rel_close(a, e, 1e-5);
        for (a, e) in lg_scan.data().iter().zip(lg_seq.data()) {
            assert!(close(*a, *e), "logits {a} vs {e}");
        }
        for (a, e) in lane_scan.lam.data().iter().zip(lane_seq.lam.data())
        {
            assert!(close(*a, *e), "lam {a} vs {e}");
        }
        for (a, e) in lane_scan.eta.data().iter().zip(lane_seq.eta.data())
        {
            assert!(close(*a, *e), "eta {a} vs {e}");
        }
        // conv windows of layers > 0 see the previous layer's scan
        // output, so they too agree at the conformance tolerance (layer
        // 0 is bit-exact, later layers within 1e-5)
        for (a, e) in
            lane_scan.conv.data().iter().zip(lane_seq.conv.data())
        {
            assert!(close(*a, *e), "conv {a} vs {e}");
        }
    }

    #[test]
    fn prefill_batch_fused_and_fallback_agree() {
        // one fused (slots × time) round vs the trait's per-slot
        // fallback: same lanes, same results within the scan tolerance
        let be = backend();
        let st = be.init_state().unwrap();
        let a: Vec<i32> = (0..9).map(|i| i % 16).collect();
        let b: Vec<i32> = vec![7];
        let c: Vec<i32> = (0..13).map(|i| (i * 3) % 16).collect();
        let lanes: Vec<(usize, &[i32])> =
            vec![(0, &a[..]), (1, &b[..]), (2, &c[..])];
        let fused = be.prefill_batch(&lanes, &st);
        let fallback = per_slot_prefill(&SeqOnly(backend()), &lanes, &st);
        assert_eq!(fused.len(), 3);
        let close =
            |a: f32, e: f32| crate::testing::rel_close(a, e, 1e-5);
        for ((fs, fr), (ss, sr)) in fused.iter().zip(&fallback) {
            assert_eq!(fs, ss);
            let (flg, flane) = fr.as_ref().unwrap();
            let (slg, slane) = sr.as_ref().unwrap();
            for (x, e) in flg.data().iter().zip(slg.data()) {
                assert!(close(*x, *e), "slot {fs} logits {x} vs {e}");
            }
            for (x, e) in flane.lam.data().iter().zip(slane.lam.data()) {
                assert!(close(*x, *e), "slot {fs} lam {x} vs {e}");
            }
            for (x, e) in flane.eta.data().iter().zip(slane.eta.data()) {
                assert!(close(*x, *e), "slot {fs} eta {x} vs {e}");
            }
        }
    }

    #[test]
    fn prefill_batch_fused_matches_per_slot_override_bit_exact() {
        // the fused round chains each lane sequentially, and the native
        // per-slot prefill under the Auto plan resolves sequential at
        // chunk sizes — so the two native paths agree bit-for-bit
        let be = backend();
        let st = be.init_state().unwrap();
        let a: Vec<i32> = (0..9).map(|i| i % 16).collect();
        let c: Vec<i32> = (0..13).map(|i| (i * 3) % 16).collect();
        let lanes: Vec<(usize, &[i32])> = vec![(1, &a[..]), (2, &c[..])];
        let fused = be.prefill_batch(&lanes, &st);
        for (slot, res) in fused {
            let toks = if slot == 1 { &a } else { &c };
            let tok_t =
                IntTensor::new(&[toks.len()], toks.clone()).unwrap();
            let (lg, lane) = be.prefill(&tok_t, slot, &st).unwrap();
            let (flg, flane) = res.unwrap();
            assert_eq!(flg.data(), lg.data(), "slot {slot}");
            assert_eq!(flane.lam.data(), lane.lam.data());
            assert_eq!(flane.eta.data(), lane.eta.data());
            assert_eq!(flane.conv.data(), lane.conv.data());
        }
    }

    /// Fails `prefill` on one designated slot — the fault-injection
    /// shape the engine's per-request isolation test uses.
    struct FaultySlot(NativeBackend, usize);

    impl DecodeBackend for FaultySlot {
        fn batch(&self) -> usize {
            self.0.batch()
        }
        fn vocab(&self) -> usize {
            self.0.vocab()
        }
        fn kind(&self) -> &'static str {
            "faulty"
        }
        fn init_state(&self) -> Result<DecodeState> {
            self.0.init_state()
        }
        fn step(&self, tokens: &IntTensor, state: &DecodeState)
                -> Result<(Tensor, DecodeState)> {
            self.0.step(tokens, state)
        }
        fn prefill_is_parallel(&self) -> bool {
            true
        }
        fn prefill(&self, tokens: &IntTensor, slot: usize,
                   state: &DecodeState) -> Result<(Tensor, DecodeState)> {
            if slot == self.1 {
                bail!("injected prefill fault on slot {slot}");
            }
            self.0.prefill(tokens, slot, state)
        }
    }

    #[test]
    fn prefill_batch_isolates_a_failing_lane() {
        let be = FaultySlot(backend(), 1);
        let st = be.init_state().unwrap();
        let a: Vec<i32> = vec![1, 2, 3];
        let lanes: Vec<(usize, &[i32])> =
            vec![(0, &a[..]), (1, &a[..]), (2, &a[..])];
        let out = be.prefill_batch(&lanes, &st);
        assert_eq!(out.len(), 3);
        assert!(out[0].1.is_ok());
        assert!(out[1].1.is_err(), "slot 1 must carry its own error");
        assert!(out[2].1.is_ok(), "slot 2 must survive slot 1's fault");
    }

    #[test]
    fn prefill_batch_degrades_structural_errors_per_lane() {
        // an out-of-range slot fails only its own lane on the native
        // override too (the fused call degrades to the per-slot loop)
        let be = backend();
        let st = be.init_state().unwrap();
        let a: Vec<i32> = vec![4, 5];
        let lanes: Vec<(usize, &[i32])> = vec![(0, &a[..]), (9, &a[..])];
        let out = be.prefill_batch(&lanes, &st);
        assert_eq!(out.len(), 2);
        assert!(out[0].1.is_ok());
        assert!(out[1].1.is_err());
    }

    #[test]
    fn prefill_rejects_empty_tokens_and_bad_slot() {
        let be = backend();
        let st = be.init_state().unwrap();
        let empty = IntTensor::new(&[0], vec![]).unwrap();
        assert!(be.prefill(&empty, 0, &st).is_err());
        assert!(SeqOnly(backend()).prefill(&empty, 0, &st).is_err());
        let one = IntTensor::new(&[1], vec![5]).unwrap();
        assert!(be.prefill(&one, 3, &st).is_err());
        assert!(SeqOnly(backend()).prefill(&one, 3, &st).is_err());
    }

    #[test]
    fn decode_state_slot_extracts_one_lane() {
        let be = backend();
        let st = be.init_state().unwrap();
        let lane = st.slot(1).unwrap();
        assert_eq!(lane.conv.shape(), &[2, 1, 2, 8]);
        assert_eq!(lane.lam.shape(), &[2, 1, 2, 8]);
        assert_eq!(st.batch(), 3);
        assert_eq!(lane.batch(), 1);
        assert!(st.slot(3).is_err());
    }

    #[test]
    fn from_values_roundtrip_matches_seeded() {
        let be = backend();
        let vals = be.lm().to_values();
        let be2 = NativeBackend::from_values(&vals, 3, true, true).unwrap();
        let toks = IntTensor::new(&[3], vec![5, 6, 7]).unwrap();
        let st = be.init_state().unwrap();
        let (a, _) = be.step(&toks, &st).unwrap();
        let (b, _) = be2.step(&toks, &st).unwrap();
        assert_eq!(a.data(), b.data());
    }
}

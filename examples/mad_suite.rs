//! MAD synthetic suite (paper Fig. 5a) for one or more mixers.
//!
//!   cargo run --release --example mad_suite [steps] [models,comma,sep]
//!
//! Default: 150 steps, models "kla,gla".  Models with default-manifest
//! artifacts: kla, kla_plus, mamba, gla, gdn, kla_nonoise, kla_noou.

use anyhow::Result;
use kla::config::TrainConfig;
use kla::data::{task_by_name, MAD_TASKS};
use kla::runtime::Runtime;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(150);
    let models: Vec<String> = args
        .get(2)
        .map(|s| s.split(',').map(|x| x.to_string()).collect())
        .unwrap_or_else(|| vec!["kla".into(), "gla".into()]);

    let rt = Runtime::discover()?;
    println!("MAD suite: {} steps/task, models {models:?}", steps);
    println!("{:16} {}", "task",
             models.iter().map(|m| format!("{m:>12}"))
                 .collect::<String>());
    let mut averages = vec![0.0f64; models.len()];
    for task_name in MAD_TASKS {
        let task = task_by_name(task_name).unwrap();
        let mut row = format!("{task_name:16}");
        for (mi, model) in models.iter().enumerate() {
            let cfg = TrainConfig {
                artifact: format!("mad_{model}"),
                steps,
                seed: 0,
                eval_every: 0,
                eval_batches: 6,
                log_every: steps,
                checkpoint_dir: None,
                target_accuracy: None,
            };
            let out = kla::train::run(&rt, &cfg, task.as_ref())?;
            row.push_str(&format!("{:>12.4}", out.accuracy()));
            averages[mi] += out.accuracy() / MAD_TASKS.len() as f64;
        }
        println!("{row}");
    }
    let mut avg_row = format!("{:16}", "AVERAGE");
    for a in &averages {
        avg_row.push_str(&format!("{a:>12.4}"));
    }
    println!("{avg_row}");
    Ok(())
}

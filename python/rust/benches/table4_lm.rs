fn main() {}

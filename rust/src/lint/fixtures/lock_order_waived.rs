//! Waiver fixture for the `lock-order` pass: every finding the bad
//! fixture seeds is suppressed here by a reasoned waiver, so the
//! waiver path (and its used-count accounting) is itself tested.
//! Never compiled — `include_str!`-ed by unit tests only.

use std::sync::{Condvar, Mutex};

pub struct S {
    pub a: Mutex<u32>,
    pub b: Mutex<u32>,
    pub cv: Condvar,
}

pub fn ab(s: &S) {
    let ga = s.a.lock().unwrap();
    // lint: allow(lock-order, fixture: b nests under a by construction)
    let gb = s.b.lock().unwrap();
    drop(gb);
    drop(ga);
}

pub fn ba(s: &S) {
    let gb = s.b.lock().unwrap();
    // lint: allow(lock-order, fixture: teardown path, a is uncontended)
    let ga = s.a.lock().unwrap();
    drop(ga);
    drop(gb);
}

pub fn waits_wrong(s: &S) {
    let ga = s.a.lock().unwrap();
    // lint: allow(lock-order, fixture: single wakeup by protocol)
    let _g = s.cv.wait(ga).unwrap();
}

pub fn waits_holding(s: &S) {
    let gb = s.b.lock().unwrap();
    let ga = s.a.lock().unwrap();
    loop {
        // lint: allow(lock-order, fixture: gb intentionally held here)
        let _g = s.cv.wait(ga).unwrap();
    }
}

//! Serving throughput/latency: continuous batching vs batch-of-one, and
//! batching-window sensitivity — the L3 coordinator's own performance
//! characteristics (EXPERIMENTS.md §Perf / L3).

use kla::bench::Suite;
use kla::config::ServeConfig;
use kla::kla::NativeLmConfig;
use kla::runtime::{NativeBackend, Runtime};
use kla::serve::{serve, serve_native, Client, RequestOpts, StreamEvent};
use kla::util::{Json, Pcg64, Stats};

fn load_once(addr: &str, n_requests: usize, prompt_len: usize,
             max_new: usize) -> (f64, Stats) {
    load_once_opts(addr, n_requests, prompt_len, max_new,
                   &RequestOpts::default())
}

fn load_once_opts(addr: &str, n_requests: usize, prompt_len: usize,
                  max_new: usize, opts: &RequestOpts) -> (f64, Stats) {
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for i in 0..n_requests {
        let addr = addr.to_string();
        let mut opts = opts.clone();
        // per-request seed so sampled rows are reproducible run to run
        if opts.temperature.is_some() {
            opts.seed = Some(i as u64);
        }
        joins.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let prompt: Vec<i32> = (0..prompt_len)
                .map(|j| ((i * 13 + j) % 200) as i32)
                .collect();
            let r = c.request_opts(&prompt, max_new, &opts).unwrap();
            r.req("total_ms").unwrap().as_f64().unwrap()
        }));
    }
    let mut lat = Stats::new();
    for j in joins {
        lat.push(j.join().unwrap());
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let toks = (n_requests * max_new) as f64;
    (toks / wall_s, lat)
}

/// Time-to-first-token under the v2 streaming protocol: concurrent
/// streaming clients, each measuring submit -> first `token` event.
/// TTFT is the metric chunked scan prefill actually moves (a 64-token
/// prompt is one prefill call instead of 64 interleaved steps before
/// the first sample exists), so it gets its own row next to the
/// whole-request latency percentiles.
fn ttft_once(addr: &str, n_requests: usize, prompt_len: usize,
             max_new: usize) -> Stats {
    let mut joins = Vec::new();
    for i in 0..n_requests {
        let addr = addr.to_string();
        joins.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let prompt: Vec<i32> = (0..prompt_len)
                .map(|j| ((i * 13 + j) % 200) as i32)
                .collect();
            let t0 = std::time::Instant::now();
            let mut ttft_ms = None;
            for ev in c
                .stream(&prompt, max_new, &RequestOpts::default())
                .unwrap()
            {
                if let StreamEvent::Token { index: 0, .. } = ev {
                    ttft_ms = Some(t0.elapsed().as_secs_f64() * 1e3);
                }
                // keep draining to the terminal event so the engine
                // finishes cleanly before the next load phase
            }
            ttft_ms
        }));
    }
    let mut ttft = Stats::new();
    let mut missing = 0usize;
    for j in joins {
        // a stream that ended without any token event (err / transport
        // failure) must not poison the percentile sort with a NaN —
        // count it out loud instead
        match j.join().unwrap() {
            Some(ms) => ttft.push(ms),
            None => missing += 1,
        }
    }
    if missing > 0 {
        println!("note: {missing} ttft stream(s) ended without a token");
    }
    ttft
}

fn main() {
    let mut suite = Suite::new("serve_throughput");

    // ---- native backend: always runs (no artifacts required) ----
    // prompt-heavy load (64-token prompts, 8 new tokens) so the chunked
    // scan prefill shows up: chunk=1 is the legacy token-per-iteration
    // baseline, chunk=64 consumes a whole prompt per prefill call
    for (slots, chunk, label) in
        [(8usize, 64usize, "native_batch8_chunk64"),
         (8, 1, "native_batch8_chunk1"),
         (1, 64, "native_batch1_chunk64")]
    {
        for window_us in [100u64, 1000] {
            let cfg = ServeConfig {
                addr: "127.0.0.1:0".into(),
                backend: "native".into(),
                batch_window_us: window_us,
                max_new_tokens: 8,
                prefill_chunk: chunk,
                ..Default::default()
            };
            let backend =
                NativeBackend::seeded(&NativeLmConfig::default(), 0, slots);
            let handle = serve_native(backend, &cfg).unwrap();
            let addr = handle.addr.clone();
            let _ = load_once(&addr, 2, 64, 2); // warm
            let (tps, lat) = load_once(&addr, 24, 64, 8);
            // streaming TTFT over the same 64-token prompts: chunk=1
            // pays one engine iteration per prompt token before the
            // first sample, chunk=64 one scan-prefill call
            let ttft = ttft_once(&addr, 8, 64, 8);
            let stats = handle.stop().unwrap();
            suite.metric_row(
                &format!("{label}/window{window_us}us"),
                vec![
                    ("tokens_per_s".into(), tps),
                    ("p50_ms".into(), lat.percentile(50.0)),
                    ("p99_ms".into(), lat.percentile(99.0)),
                    ("engine_step_ms".into(), stats.mean_step_ms()),
                    ("occupancy".into(),
                     stats.batch_occupancy.iter().sum::<f64>()
                         / stats.batch_occupancy.len().max(1) as f64),
                ],
            );
            // prefill throughput gets its own row, so the scan-prefill
            // win is measured separately from decode tokens/s
            suite.metric_row(
                &format!("{label}/window{window_us}us/prefill"),
                vec![
                    ("prefill_tok_s".into(),
                     stats.prefill_tokens_per_sec()),
                    ("decode_tok_s".into(), stats.tokens_per_sec()),
                    ("prefill_tokens".into(),
                     stats.prefill_tokens as f64),
                ],
            );
            // time-to-first-token through the streaming protocol — the
            // latency chunked prefill buys down for prompt-heavy load
            suite.metric_row(
                &format!("{label}/window{window_us}us/ttft"),
                vec![
                    ("ttft_p50_ms".into(), ttft.percentile(50.0)),
                    ("ttft_p99_ms".into(), ttft.percentile(99.0)),
                ],
            );
        }
    }

    // ---- sampling overhead: seeded temperature/top-p vs greedy ----
    // same load as native_batch8_chunk64/window1000us, but every request
    // samples (temperature 0.9, top_p 0.95, per-request seed), so the
    // per-lane softmax + nucleus cost shows up next to the greedy row
    {
        let cfg = ServeConfig {
            addr: "127.0.0.1:0".into(),
            backend: "native".into(),
            batch_window_us: 1000,
            max_new_tokens: 8,
            prefill_chunk: 64,
            ..Default::default()
        };
        let backend =
            NativeBackend::seeded(&NativeLmConfig::default(), 0, 8);
        let handle = serve_native(backend, &cfg).unwrap();
        let addr = handle.addr.clone();
        let opts = RequestOpts {
            temperature: Some(0.9),
            top_p: Some(0.95),
            ..Default::default()
        };
        let _ = load_once_opts(&addr, 2, 64, 2, &opts); // warm
        let (tps, lat) = load_once_opts(&addr, 24, 64, 8, &opts);
        let stats = handle.stop().unwrap();
        suite.metric_row(
            "native_batch8_chunk64_sampled/window1000us",
            vec![
                ("tokens_per_s".into(), tps),
                ("p50_ms".into(), lat.percentile(50.0)),
                ("p99_ms".into(), lat.percentile(99.0)),
                ("engine_step_ms".into(), stats.mean_step_ms()),
                ("occupancy".into(),
                 stats.batch_occupancy.iter().sum::<f64>()
                     / stats.batch_occupancy.len().max(1) as f64),
            ],
        );
    }

    // machine-readable rows for BENCH_serve.json (burst + Zipf
    // scenarios below both feed it; one write at the end of the native
    // section, uploaded as a CI artifact)
    let mut bench_rows: Vec<Json> = Vec::new();

    // ---- burst: many concurrent long prompts in one admission wave ----
    // 24 clients fire 256-token prompts at 8 slots simultaneously, tiny
    // decode tail — the shape the fused (slots x time) prefill round is
    // for: every admitted slot's chunk rides ONE multi-dimensional scan
    // per engine iteration instead of B serial per-slot scans.  chunk=1
    // is the legacy token-per-iteration baseline on identical load; the
    // aggregate row is total burst prefill tokens over wall time, the
    // fleet-level number a serving deployment actually sees.
    {
        const BURST_REQUESTS: usize = 24;
        const BURST_PROMPT: usize = 256;
        const BURST_NEW: usize = 2;
        for (chunk, label) in
            [(64usize, "fused_chunk64"), (1, "legacy_chunk1")]
        {
            let cfg = ServeConfig {
                addr: "127.0.0.1:0".into(),
                backend: "native".into(),
                batch_window_us: 1000,
                max_new_tokens: BURST_NEW,
                prefill_chunk: chunk,
                ..Default::default()
            };
            let backend =
                NativeBackend::seeded(&NativeLmConfig::default(), 0, 8);
            let handle = serve_native(backend, &cfg).unwrap();
            let addr = handle.addr.clone();
            let _ = load_once(&addr, 2, 16, 1); // warm
            let t0 = std::time::Instant::now();
            let (_, lat) =
                load_once(&addr, BURST_REQUESTS, BURST_PROMPT, BURST_NEW);
            let wall_s = t0.elapsed().as_secs_f64();
            let stats = handle.stop().unwrap();
            // wall-clock aggregate over the burst's own prefill work
            // (each request prefills prompt-1 tokens; the 2-request
            // warm pass is excluded from the numerator)
            let burst_tokens =
                (BURST_REQUESTS * (BURST_PROMPT - 1)) as f64;
            let aggregate_tok_s = burst_tokens / wall_s;
            suite.metric_row(
                &format!("burst_long_prompts/{label}"),
                vec![
                    ("aggregate_prefill_tok_s".into(), aggregate_tok_s),
                    ("prefill_tok_s".into(),
                     stats.prefill_tokens_per_sec()),
                    ("p50_ms".into(), lat.percentile(50.0)),
                    ("p99_ms".into(), lat.percentile(99.0)),
                    ("wall_s".into(), wall_s),
                ],
            );
            bench_rows.push(Json::obj(vec![
                ("scenario",
                 Json::str(&format!("burst_long_prompts/{label}"))),
                ("aggregate_prefill_tok_s", Json::num(aggregate_tok_s)),
                ("prefill_tok_s",
                 Json::num(stats.prefill_tokens_per_sec())),
                ("p50_ms", Json::num(lat.percentile(50.0))),
                ("p99_ms", Json::num(lat.percentile(99.0))),
                ("wall_s", Json::num(wall_s)),
            ]));
        }
    }

    // ---- belief-state prefix cache: Zipf shared-prefix scenario ----
    // 16 system prompts drawn Zipf(s = 1.1) — the head prefixes recur
    // constantly, like a fleet of agents sharing a handful of system
    // prompts.  Cold = cache off (every request prefills its full
    // 272-token prompt); warm = 64 MiB cache primed with one prefill-
    // only pass per prefix, so repeat prefixes restore a belief-state
    // snapshot and skip ~256 of those tokens.  The rows land both in
    // the suite table and in BENCH_serve.json (machine-readable perf
    // trajectory, uploaded as a CI artifact).
    {
        const N_PREFIXES: usize = 16;
        const PREFIX_LEN: usize = 256;
        const SUFFIX_LEN: usize = 16;
        const N_REQUESTS: usize = 48;
        const MAX_NEW: usize = 4;
        let weights: Vec<f64> = (1..=N_PREFIXES)
            .map(|k| 1.0 / (k as f64).powf(1.1))
            .collect();
        let mut rng = Pcg64::seeded(42);
        let prefixes: Vec<Vec<i32>> = (0..N_PREFIXES)
            .map(|p| (0..PREFIX_LEN)
                .map(|j| ((p * 31 + j * 7) % 200) as i32)
                .collect())
            .collect();
        // zipf-assigned prefix + a unique 16-token suffix per request,
        // so warm hits are PARTIAL (block-aligned prefix restore) —
        // the realistic shape, not exact-prompt resubmission
        let prompts: Vec<Vec<i32>> = (0..N_REQUESTS)
            .map(|i| {
                let mut v = prefixes[rng.weighted(&weights)].clone();
                v.extend((0..SUFFIX_LEN)
                    .map(|j| ((i * 13 + j) % 200) as i32));
                v
            })
            .collect();

        for (cache_mb, label) in [(0usize, "cold"), (64, "warm")] {
            let cfg = ServeConfig {
                addr: "127.0.0.1:0".into(),
                backend: "native".into(),
                batch_window_us: 1000,
                max_new_tokens: MAX_NEW,
                prefill_chunk: 64,
                prefix_cache_bytes: cache_mb << 20,
                ..Default::default()
            };
            let backend =
                NativeBackend::seeded(&NativeLmConfig::default(), 0, 8);
            let handle = serve_native(backend, &cfg).unwrap();
            let addr = handle.addr.clone();
            if cache_mb > 0 {
                // prime: one prefill-only request per prefix seeds the
                // cache, so the warm rows measure steady-state reuse
                let mut c = Client::connect(&addr).unwrap();
                for p in &prefixes {
                    let _ = c.request(p, 0).unwrap();
                }
            }
            // waves of 8 concurrent streaming clients, each measuring
            // submit -> first token event (TTFT is what the restored
            // prefix buys down: ~256 prompt tokens never prefilled)
            let mut ttft = Stats::new();
            for wave in prompts.chunks(8) {
                let mut joins = Vec::new();
                for prompt in wave {
                    let addr = addr.to_string();
                    let prompt = prompt.clone();
                    joins.push(std::thread::spawn(move || {
                        let mut c = Client::connect(&addr).unwrap();
                        let t0 = std::time::Instant::now();
                        let mut first = None;
                        for ev in c
                            .stream(&prompt, MAX_NEW,
                                    &RequestOpts::default())
                            .unwrap()
                        {
                            if let StreamEvent::Token { index: 0, .. } =
                                ev
                            {
                                first = Some(
                                    t0.elapsed().as_secs_f64() * 1e3);
                            }
                        }
                        first
                    }));
                }
                for j in joins {
                    if let Some(ms) = j.join().unwrap() {
                        ttft.push(ms);
                    }
                }
            }
            let stats = handle.stop().unwrap();
            let looked_up = stats.prefix_hits + stats.prefix_partial_hits
                + stats.prefix_misses;
            let hit_rate = if looked_up > 0 {
                (stats.prefix_hits + stats.prefix_partial_hits) as f64
                    / looked_up as f64
            } else {
                0.0
            };
            let prefill_tok_s = stats.prefill_tokens_per_sec();
            suite.metric_row(
                &format!("zipf_shared_prefix/{label}"),
                vec![
                    ("prefill_tok_s".into(), prefill_tok_s),
                    ("ttft_p50_ms".into(), ttft.percentile(50.0)),
                    ("ttft_p99_ms".into(), ttft.percentile(99.0)),
                    ("cache_hit_rate".into(), hit_rate),
                    ("cached_tokens".into(),
                     stats.prefix_cached_tokens as f64),
                ],
            );
            bench_rows.push(Json::obj(vec![
                ("scenario",
                 Json::str(&format!("zipf_shared_prefix/{label}"))),
                ("prefill_tok_s", Json::num(prefill_tok_s)),
                ("ttft_p50_ms", Json::num(ttft.percentile(50.0))),
                ("ttft_p99_ms", Json::num(ttft.percentile(99.0))),
                ("cache_hit_rate", Json::num(hit_rate)),
                ("cached_tokens",
                 Json::num(stats.prefix_cached_tokens as f64)),
            ]));
        }
        let report = Json::obj(vec![
            ("bench", Json::str("serve")),
            ("rows", Json::Arr(bench_rows)),
        ]);
        if std::fs::write("BENCH_serve.json", report.to_pretty()).is_ok()
        {
            println!("[bench] wrote BENCH_serve.json");
        }
    }

    // ---- XLA artifact backend: skips without artifacts ----
    let rt = match Runtime::discover() {
        Ok(rt) => rt,
        Err(e) => {
            println!("note: xla rows skipped (no artifacts): {e}");
            suite.finish();
            return;
        }
    };
    let init = rt.load("lm_kla_init").unwrap();
    let params = init.run(&[]).unwrap();

    for (artifact, label) in [("serve_kla_b8", "batch8"),
                              ("serve_kla_b1", "batch1")] {
        for window_us in [100u64, 1000] {
            let cfg = ServeConfig {
                addr: "127.0.0.1:0".into(),
                artifact: artifact.into(),
                batch_window_us: window_us,
                max_new_tokens: 8,
                ..Default::default()
            };
            let handle = serve(rt.dir().to_path_buf(), artifact.into(),
                               params.clone(), &cfg).unwrap();
            let addr = handle.addr.clone();
            // warm the engine (first step compiles nothing but touches
            // the executable)
            let _ = load_once(&addr, 2, 4, 2);
            let (tps, lat) = load_once(&addr, 24, 4, 8);
            let stats = handle.stop().unwrap();
            suite.metric_row(
                &format!("{label}/window{window_us}us"),
                vec![
                    ("tokens_per_s".into(), tps),
                    ("p50_ms".into(), lat.percentile(50.0)),
                    ("p99_ms".into(), lat.percentile(99.0)),
                    ("engine_step_ms".into(), stats.mean_step_ms()),
                    ("occupancy".into(),
                     stats.batch_occupancy.iter().sum::<f64>()
                         / stats.batch_occupancy.len().max(1) as f64),
                ],
            );
            suite.metric_row(
                &format!("{label}/window{window_us}us/prefill"),
                vec![
                    ("prefill_tok_s".into(),
                     stats.prefill_tokens_per_sec()),
                    ("decode_tok_s".into(), stats.tokens_per_sec()),
                    ("prefill_tokens".into(),
                     stats.prefill_tokens as f64),
                ],
            );
        }
    }
    suite.finish();
}

"""Shared L2 building blocks: initialisers, norms, causal conv, losses.

Parameters are plain nested dicts (name -> array or sub-dict).  The AOT
bridge flattens them in sorted-key order (`flatten_params`) and the Rust
side consumes the layout from the artifact's meta.json, so the ordering
here is a wire format — keep it deterministic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------- params ---

def dense_init(rng: np.random.Generator, d_in: int, d_out: int,
               scale: float = 1.0) -> jnp.ndarray:
    """LeCun-normal style init (fp32)."""
    std = scale / np.sqrt(d_in)
    return jnp.asarray(rng.normal(0.0, std, size=(d_in, d_out)),
                       dtype=jnp.float32)


def flatten_params(params: dict, prefix: str = ""):
    """Deterministic (sorted-key) flattening of a nested param dict.

    Returns a list of (name, array).  This ordering IS the artifact ABI.
    """
    out = []
    for key in sorted(params.keys()):
        val = params[key]
        name = f"{prefix}{key}"
        if isinstance(val, dict):
            out.extend(flatten_params(val, prefix=name + "."))
        else:
            out.append((name, val))
    return out


def unflatten_params(template: dict, flat_list):
    """Inverse of flatten_params given the same template structure."""
    it = iter(flat_list)

    def rec(node):
        out = {}
        for key in sorted(node.keys()):
            val = node[key]
            out[key] = rec(val) if isinstance(val, dict) else next(it)
        return out

    result = rec(template)
    rest = list(it)
    assert not rest, f"{len(rest)} leftover arrays in unflatten"
    return result


# ---------------------------------------------------------------- layers ---

def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * scale


def l2norm(x: jnp.ndarray, eps: float = 1e-6):
    """QK-norm (paper Fig. 7): L2-normalise over the last axis."""
    n = jnp.sqrt(jnp.sum(x * x, axis=-1, keepdims=True) + eps)
    return x / n


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray):
    """Depthwise causal 1-D convolution, kernel size K (paper: K=4).

    x: (B, T, D); w: (K, D); b: (D,).  Output (B, T, D); position t sees
    inputs t-K+1..t (left-padded with zeros).
    """
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):  # K is tiny and static: unrolled adds fuse in XLA
        out = out + xp[:, i:i + x.shape[1], :] * w[i]
    return out + b


def conv_state_step(state: jnp.ndarray, x_t: jnp.ndarray, w: jnp.ndarray,
                    b: jnp.ndarray):
    """O(1) decode-time counterpart of `causal_conv1d`.

    state: (B, K-1, D) previous inputs; x_t: (B, D) current input.
    Returns (y_t, new_state)."""
    K = w.shape[0]
    window = jnp.concatenate([state, x_t[:, None, :]], axis=1)  # (B, K, D)
    y = jnp.einsum("bkd,kd->bd", window, w) + b
    return y, window[:, 1:, :]


def silu(x):
    return x * jax.nn.sigmoid(x)


# ---------------------------------------------------------------- losses ---

def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray,
                  mask: jnp.ndarray):
    """Masked mean cross-entropy.  logits: (B, T, V); targets: (B, T) i32;
    mask: (B, T) f32 in {0, 1}."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    total = jnp.sum(mask)
    return -jnp.sum(ll * mask) / jnp.maximum(total, 1.0)


def token_accuracy(logits, targets, mask):
    """(correct_count, total_count) over masked positions."""
    pred = jnp.argmax(logits, axis=-1)
    correct = jnp.sum((pred == targets).astype(jnp.float32) * mask)
    return correct, jnp.sum(mask)


def sequence_logprob(logits, targets, mask):
    """Per-sequence summed log-probability of `targets` over masked
    positions — the zero-shot multiple-choice scoring primitive.
    Returns (B,)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.sum(ll * mask, axis=-1)

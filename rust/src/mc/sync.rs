//! Shimmable sync primitives (DESIGN.md §S19).
//!
//! Normal builds re-export `std::sync` — importing from `mc::sync`
//! instead of `std::sync` is free and changes nothing.  Under
//! `--features mc-shim` the same names resolve to shims that wrap the
//! std primitive *and* mirror its state into the controlled scheduler
//! ([`crate::mc::sched`]): every acquire, release, wait, notify,
//! send, recv, load and store becomes a scheduling point.
//!
//! Shim objects constructed outside a model execution (no scheduler
//! on the current thread) behave exactly like std forever, so the
//! whole test suite can run with the feature enabled.  Objects that
//! ARE modelled must be created *inside* the model closure; mixing a
//! std-constructed lock into a model would block for real, outside
//! the scheduler's control.
//!
//! In-model atomic accesses are sequentially consistent regardless of
//! the `Ordering` argument — the checker explores interleavings, not
//! weak-memory reorderings; ordering discipline is audited statically
//! by the `atomic-ordering` lint pass.

#[cfg(not(feature = "mc-shim"))]
pub use std::sync::atomic::{AtomicBool, AtomicUsize};
#[cfg(not(feature = "mc-shim"))]
pub use std::sync::mpsc::{channel, Receiver, Sender};
#[cfg(not(feature = "mc-shim"))]
pub use std::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};

#[cfg(feature = "mc-shim")]
pub use shim::{
    channel, AtomicBool, AtomicUsize, Condvar, Mutex, MutexGuard,
    Receiver, Sender, WaitTimeoutResult,
};

#[cfg(feature = "mc-shim")]
mod shim {
    use std::sync::atomic::Ordering;
    use std::sync::mpsc::{RecvError, SendError, TryRecvError};
    use std::sync::{LockResult, PoisonError};
    use std::time::Duration;

    use crate::mc::sched::{self, Intent, Note, Obj, ObjKind, ObjRef};

    // -----------------------------------------------------------------
    // Mutex
    // -----------------------------------------------------------------

    // no Default impls for Mutex/Condvar: construction must go
    // through `new` so the object registers with the scheduler.
    #[derive(Debug)]
    pub struct Mutex<T> {
        inner: std::sync::Mutex<T>,
        mc: ObjRef,
    }

    pub struct MutexGuard<'a, T> {
        lock: &'a Mutex<T>,
        inner: Option<std::sync::MutexGuard<'a, T>>,
        /// True when the acquisition is tracked by the model (the
        /// drop must then clear the model's `held_by`).
        model: bool,
    }

    impl<T> Mutex<T> {
        pub fn new(v: T) -> Mutex<T> {
            Mutex {
                inner: std::sync::Mutex::new(v),
                mc: ObjRef::register(ObjKind::Mutex),
            }
        }

        pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
            if let Some((exec, obj, me)) = self.mc.handle() {
                exec.op(me, Intent::Lock(obj));
                // The model granted us the lock, so the inner mutex
                // is free (holders release it before parking).
                let g = self
                    .inner
                    .lock()
                    .unwrap_or_else(PoisonError::into_inner);
                Ok(MutexGuard {
                    lock: self,
                    inner: Some(g),
                    model: true,
                })
            } else {
                match self.inner.lock() {
                    Ok(g) => Ok(MutexGuard {
                        lock: self,
                        inner: Some(g),
                        model: false,
                    }),
                    Err(e) => Err(PoisonError::new(MutexGuard {
                        lock: self,
                        inner: Some(e.into_inner()),
                        model: false,
                    })),
                }
            }
        }
    }

    impl<T> std::ops::Deref for MutexGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            self.inner.as_ref().expect("mc: guard already released")
        }
    }

    impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            self.inner.as_mut().expect("mc: guard already released")
        }
    }

    impl<T> Drop for MutexGuard<'_, T> {
        fn drop(&mut self) {
            // Release order matters: free the inner mutex BEFORE the
            // model marks the lock free, so a model grant always
            // finds the inner mutex uncontended.  Never a scheduling
            // point (drops run during unwinding; the interleavings
            // are covered by the next thread's op points).
            drop(self.inner.take());
            if self.model {
                self.lock.mc.update(|o| {
                    if let Obj::Mutex { held_by } = o {
                        *held_by = None;
                    }
                });
            }
        }
    }

    // -----------------------------------------------------------------
    // Condvar
    // -----------------------------------------------------------------

    /// Mirror of `std::sync::WaitTimeoutResult` (std's has no public
    /// constructor, so the shim defines its own).
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub struct WaitTimeoutResult {
        timed_out: bool,
    }

    impl WaitTimeoutResult {
        pub fn timed_out(&self) -> bool {
            self.timed_out
        }
    }

    #[derive(Debug)]
    pub struct Condvar {
        inner: std::sync::Condvar,
        mc: ObjRef,
    }

    impl Condvar {
        pub fn new() -> Condvar {
            Condvar {
                inner: std::sync::Condvar::new(),
                mc: ObjRef::register(ObjKind::Condvar),
            }
        }

        pub fn wait<'a, T>(
            &self,
            guard: MutexGuard<'a, T>,
        ) -> LockResult<MutexGuard<'a, T>> {
            self.wait_inner(guard, None).map(|(g, _)| g)
        }

        pub fn wait_timeout<'a, T>(
            &self,
            guard: MutexGuard<'a, T>,
            dur: Duration,
        ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
            self.wait_inner(guard, Some(dur))
        }

        fn wait_inner<'a, T>(
            &self,
            mut guard: MutexGuard<'a, T>,
            dur: Option<Duration>,
        ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
            let lock = guard.lock;
            if guard.model {
                let (exec, cv, me) = self
                    .mc
                    .handle()
                    .expect("mc: modelled mutex waited on foreign condvar");
                let mobj = lock
                    .mc
                    .obj_id()
                    .expect("mc: modelled guard without object id");
                // The Wait intent releases the model lock atomically;
                // disarm the guard so its drop does not double-free.
                guard.model = false;
                drop(guard);
                let note = exec.op(
                    me,
                    Intent::Wait {
                        cv,
                        lock: mobj,
                        timed: dur.is_some(),
                    },
                );
                let g = lock
                    .inner
                    .lock()
                    .unwrap_or_else(PoisonError::into_inner);
                return Ok((
                    MutexGuard {
                        lock,
                        inner: Some(g),
                        model: true,
                    },
                    WaitTimeoutResult {
                        timed_out: note == Note::TimedOut,
                    },
                ));
            }
            // outside any model: plain std behaviour
            let g = guard.inner.take().expect("mc: guard already released");
            drop(guard);
            let remap = |g: std::sync::MutexGuard<'a, T>, t: bool| {
                (
                    MutexGuard {
                        lock,
                        inner: Some(g),
                        model: false,
                    },
                    WaitTimeoutResult { timed_out: t },
                )
            };
            match dur {
                None => match self.inner.wait(g) {
                    Ok(g) => Ok(remap(g, false)),
                    Err(e) => Err(PoisonError::new(remap(
                        e.into_inner(),
                        false,
                    ))),
                },
                Some(d) => match self.inner.wait_timeout(g, d) {
                    Ok((g, t)) => Ok(remap(g, t.timed_out())),
                    Err(e) => {
                        let (g, t) = e.into_inner();
                        Err(PoisonError::new(remap(g, t.timed_out())))
                    }
                },
            }
        }

        pub fn notify_one(&self) {
            if let Some((exec, cv, me)) = self.mc.handle() {
                exec.op(me, Intent::Step);
                exec.notify(cv, false);
            } else {
                self.inner.notify_one();
            }
        }

        pub fn notify_all(&self) {
            if let Some((exec, cv, me)) = self.mc.handle() {
                exec.op(me, Intent::Step);
                exec.notify(cv, true);
            } else {
                self.inner.notify_all();
            }
        }
    }

    // -----------------------------------------------------------------
    // atomics
    // -----------------------------------------------------------------

    // In-model accesses yield a scheduling point and then perform the
    // real access; the model explores sequentially consistent
    // interleavings only (module docs), so the in-model access uses
    // SeqCst regardless of the requested ordering.

    #[derive(Debug, Default)]
    pub struct AtomicBool {
        inner: std::sync::atomic::AtomicBool,
    }

    impl AtomicBool {
        pub const fn new(v: bool) -> AtomicBool {
            AtomicBool {
                inner: std::sync::atomic::AtomicBool::new(v),
            }
        }

        pub fn load(&self, ord: Ordering) -> bool {
            if sched::step_point() {
                // ord: in-model accesses are SeqCst by construction;
                // the requested ordering is audited statically.
                self.inner.load(Ordering::SeqCst)
            } else {
                self.inner.load(ord)
            }
        }

        pub fn store(&self, v: bool, ord: Ordering) {
            if sched::step_point() {
                // ord: in-model accesses are SeqCst by construction;
                // the requested ordering is audited statically.
                self.inner.store(v, Ordering::SeqCst)
            } else {
                self.inner.store(v, ord)
            }
        }

        pub fn swap(&self, v: bool, ord: Ordering) -> bool {
            if sched::step_point() {
                // ord: in-model accesses are SeqCst by construction;
                // the requested ordering is audited statically.
                self.inner.swap(v, Ordering::SeqCst)
            } else {
                self.inner.swap(v, ord)
            }
        }
    }

    #[derive(Debug, Default)]
    pub struct AtomicUsize {
        inner: std::sync::atomic::AtomicUsize,
    }

    impl AtomicUsize {
        pub const fn new(v: usize) -> AtomicUsize {
            AtomicUsize {
                inner: std::sync::atomic::AtomicUsize::new(v),
            }
        }

        pub fn load(&self, ord: Ordering) -> usize {
            if sched::step_point() {
                // ord: in-model accesses are SeqCst by construction;
                // the requested ordering is audited statically.
                self.inner.load(Ordering::SeqCst)
            } else {
                self.inner.load(ord)
            }
        }

        pub fn store(&self, v: usize, ord: Ordering) {
            if sched::step_point() {
                // ord: in-model accesses are SeqCst by construction;
                // the requested ordering is audited statically.
                self.inner.store(v, Ordering::SeqCst)
            } else {
                self.inner.store(v, ord)
            }
        }

        pub fn fetch_add(&self, v: usize, ord: Ordering) -> usize {
            if sched::step_point() {
                // ord: in-model accesses are SeqCst by construction;
                // the requested ordering is audited statically.
                self.inner.fetch_add(v, Ordering::SeqCst)
            } else {
                self.inner.fetch_add(v, ord)
            }
        }

        pub fn fetch_sub(&self, v: usize, ord: Ordering) -> usize {
            if sched::step_point() {
                // ord: in-model accesses are SeqCst by construction;
                // the requested ordering is audited statically.
                self.inner.fetch_sub(v, Ordering::SeqCst)
            } else {
                self.inner.fetch_sub(v, ord)
            }
        }
    }

    // -----------------------------------------------------------------
    // mpsc channel
    // -----------------------------------------------------------------

    pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
        let (tx, rx) = std::sync::mpsc::channel();
        let mc = ObjRef::register(ObjKind::Channel);
        (
            Sender {
                inner: tx,
                mc: mc.clone(),
            },
            Receiver { inner: rx, mc },
        )
    }

    #[derive(Debug)]
    pub struct Sender<T> {
        inner: std::sync::mpsc::Sender<T>,
        mc: ObjRef,
    }

    impl<T> Sender<T> {
        pub fn send(&self, v: T) -> Result<(), SendError<T>> {
            if let Some((exec, _, me)) = self.mc.handle() {
                exec.op(me, Intent::Step);
            }
            let r = self.inner.send(v);
            if r.is_ok() {
                self.mc.update(|o| {
                    if let Obj::Channel { queued, .. } = o {
                        *queued += 1;
                    }
                });
            }
            r
        }
    }

    impl<T> Clone for Sender<T> {
        fn clone(&self) -> Sender<T> {
            self.mc.update(|o| {
                if let Obj::Channel { senders, .. } = o {
                    *senders += 1;
                }
            });
            Sender {
                inner: self.inner.clone(),
                mc: self.mc.clone(),
            }
        }
    }

    impl<T> Drop for Sender<T> {
        fn drop(&mut self) {
            // Dropping the last sender is visible (recv starts
            // returning Disconnected) — give the scheduler a point,
            // except during unwinds.
            if !std::thread::panicking() {
                if let Some((exec, _, me)) = self.mc.handle() {
                    exec.op(me, Intent::Step);
                }
            }
            self.mc.update(|o| {
                if let Obj::Channel { senders, .. } = o {
                    *senders = senders.saturating_sub(1);
                }
            });
        }
    }

    #[derive(Debug)]
    pub struct Receiver<T> {
        inner: std::sync::mpsc::Receiver<T>,
        mc: ObjRef,
    }

    impl<T> Receiver<T> {
        pub fn recv(&self) -> Result<T, RecvError> {
            if let Some((exec, obj, me)) = self.mc.handle() {
                match exec.op(me, Intent::Recv(obj)) {
                    Note::RecvClosed => Err(RecvError),
                    _ => Ok(self
                        .inner
                        .try_recv()
                        .expect("mc: channel queue out of sync")),
                }
            } else {
                self.inner.recv()
            }
        }

        pub fn iter(&self) -> Iter<'_, T> {
            Iter { rx: self }
        }

        pub fn try_recv(&self) -> Result<T, TryRecvError> {
            if let Some((exec, _, me)) = self.mc.handle() {
                exec.op(me, Intent::Step);
                let state = self
                    .mc
                    .update(|o| match o {
                        Obj::Channel { queued, senders } => {
                            if *queued > 0 {
                                *queued -= 1;
                                0
                            } else if *senders == 0 {
                                1
                            } else {
                                2
                            }
                        }
                        _ => 2,
                    })
                    .unwrap_or(2);
                match state {
                    0 => Ok(self
                        .inner
                        .try_recv()
                        .expect("mc: channel queue out of sync")),
                    1 => Err(TryRecvError::Disconnected),
                    _ => Err(TryRecvError::Empty),
                }
            } else {
                self.inner.try_recv()
            }
        }
    }

    pub struct Iter<'a, T> {
        rx: &'a Receiver<T>,
    }

    impl<T> Iterator for Iter<'_, T> {
        type Item = T;
        fn next(&mut self) -> Option<T> {
            self.rx.recv().ok()
        }
    }

    impl<'a, T> IntoIterator for &'a Receiver<T> {
        type Item = T;
        type IntoIter = Iter<'a, T>;
        fn into_iter(self) -> Iter<'a, T> {
            self.iter()
        }
    }

    pub struct IntoIter<T> {
        rx: Receiver<T>,
    }

    impl<T> Iterator for IntoIter<T> {
        type Item = T;
        fn next(&mut self) -> Option<T> {
            self.rx.recv().ok()
        }
    }

    impl<T> IntoIterator for Receiver<T> {
        type Item = T;
        type IntoIter = IntoIter<T>;
        fn into_iter(self) -> IntoIter<T> {
            IntoIter { rx: self }
        }
    }
}

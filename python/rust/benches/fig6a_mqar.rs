fn main() {}
